"""repro-san runtime sanitizer: bit-identity under REPRO_SANITIZE, every
invariant check fires on a seeded corruption, and violations emit a replayable
repro artifact (docs/ANALYSIS.md, "Runtime sanitizer")."""
import json
import os

import pytest

from repro.core import sanitize as sanitize_mod
from repro.core.fleet import FleetResult
from repro.core.pool import CapacityLedger, ClusterImageCache
from repro.core.sanitize import (FleetSanitizer, SanitizeError,
                                 sanitize_enabled)
from repro.core.scenario import Scenario, run

SCENARIOS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "scenarios")


def _scn(name):
    return Scenario.from_file(os.path.join(SCENARIOS, f"{name}.json"))


class _Worker:
    def __init__(self, idx=0, capacity=None):
        self.idx = idx
        self.ledger = CapacityLedger(capacity)


# ----------------------------------------------------------------- env knob

def test_sanitize_enabled_reads_env(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not sanitize_enabled()
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert not sanitize_enabled()
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize_enabled()


# ------------------------------------------------------------- bit-identity

@pytest.mark.parametrize("name", ["fleet_base", "churn", "sharing_fig7",
                                  "azure_scale_xl"])
def test_sanitized_run_is_bit_identical(name):
    scn = _scn(name)
    plain = run(scn, smoke=True, sanitize=False)
    checked = run(scn, smoke=True, sanitize=True)
    assert plain.to_dict() == checked.to_dict()


def test_env_knob_reaches_the_engines(monkeypatch):
    scn = _scn("fleet_base")
    plain = run(scn, smoke=True, sanitize=False)
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    checked = run(scn, smoke=True)
    assert plain.to_dict() == checked.to_dict()


# ------------------------------------------------------------ event checks

def test_event_order_regression_raises(tmp_path):
    san = FleetSanitizer("fleet", "warmswap", artifact_dir=str(tmp_path))
    san.check_event(1.0, 3, 5)
    with pytest.raises(SanitizeError, match="event-order"):
        san.check_event(1.0, 2, 6)


def test_event_order_same_tuple_never_repeats(tmp_path):
    san = FleetSanitizer("fleet", "warmswap", artifact_dir=str(tmp_path))
    san.check_event(1.0, 0, 1)
    with pytest.raises(SanitizeError, match="event-order"):
        san.check_event(1.0, 0, 1)


def test_nonfinite_event_time_raises(tmp_path):
    san = FleetSanitizer("fleet", "warmswap", artifact_dir=str(tmp_path))
    with pytest.raises(SanitizeError, match="event-order"):
        san.check_event(float("nan"), 0, 0)


def test_periodic_books_cadence(tmp_path):
    san = FleetSanitizer("fleet", "warmswap", artifact_dir=str(tmp_path))
    due = [san.check_event(float(i), 0, i)
           for i in range(2 * FleetSanitizer.BOOKS_EVERY)]
    assert sum(due) == 2


def test_negative_wait_raises(tmp_path):
    san = FleetSanitizer("fleet", "warmswap", artifact_dir=str(tmp_path))
    with pytest.raises(SanitizeError, match="negative-wait"):
        san.check_service(start=1.0, req_t=2.0, prev_busy=0.0,
                          busy_until=1.5, worker=0, fn=3)


def test_busy_regression_raises(tmp_path):
    san = FleetSanitizer("fleet", "warmswap", artifact_dir=str(tmp_path))
    with pytest.raises(SanitizeError, match="busy-regression"):
        san.check_service(start=1.0, req_t=0.5, prev_busy=2.0,
                          busy_until=3.0, worker=0, fn=3)


# ------------------------------------------------------------------- books

def test_balanced_books_pass(tmp_path):
    w = _Worker()
    w.ledger.admit("img:a", 100, now=0.0)
    san = FleetSanitizer("fleet", "warmswap", artifact_dir=str(tmp_path))
    san.check_books([w])


def test_ledger_imbalance_raises(tmp_path):
    w = _Worker()
    w.ledger.admit("img:a", 100, now=0.0)
    w.ledger._used_bytes += 7
    san = FleetSanitizer("fleet", "warmswap", artifact_dir=str(tmp_path))
    with pytest.raises(SanitizeError, match="ledger-books"):
        san.check_books([w])


def test_cluster_holder_without_pool_entry_raises(tmp_path):
    w = _Worker()
    cluster = ClusterImageCache()
    cluster.admit("img:a", 100, w.idx, now=0.0)
    san = FleetSanitizer("fleet", "warmswap", artifact_dir=str(tmp_path))
    with pytest.raises(SanitizeError, match="cluster-books"):
        san.check_books([w], cluster)

    w.ledger.admit("img:a", 100, now=0.0)
    san.check_books([w], cluster)       # consistent again


# ---------------------------------------------------------------- counters

def _result(**kw):
    base = dict(method="warmswap", n_invocations=10, n_cold=4, n_warm=6,
                total_latency_s=1.0, memory_bytes=0, n_workers=1)
    base.update(kw)
    return FleetResult(**base)


def test_conservation_holds(tmp_path):
    san = FleetSanitizer("fleet", "warmswap", artifact_dir=str(tmp_path))
    san.check_counters(_result())


def test_dropped_service_start_raises(tmp_path):
    san = FleetSanitizer("fleet", "warmswap", artifact_dir=str(tmp_path))
    with pytest.raises(SanitizeError, match="counter-conservation"):
        san.check_counters(_result(n_warm=5))


def test_requeue_widens_the_bound(tmp_path):
    san = FleetSanitizer("fleet", "warmswap", artifact_dir=str(tmp_path))
    res = _result(n_warm=7)
    res.requeued = 1
    res.worker_failures = 1
    san.check_counters(res)             # 10 <= 11 <= 11
    res.n_warm = 8                      # 12 > 11: one start too many
    with pytest.raises(SanitizeError, match="counter-conservation"):
        san.check_counters(res)


def test_negative_counter_raises(tmp_path):
    san = FleetSanitizer("fleet", "warmswap", artifact_dir=str(tmp_path))
    res = _result()
    res.pool_misses = -1
    with pytest.raises(SanitizeError, match="counter-conservation"):
        san.check_counters(res)


def test_sample_domain_violations_raise(tmp_path):
    import numpy as np
    san = FleetSanitizer("fleet", "warmswap", artifact_dir=str(tmp_path))
    ok = np.array([1.0, 2.0])
    san.check_samples(ok, np.array([0.0, 1.0]))
    with pytest.raises(SanitizeError, match="sample-domain"):
        san.check_samples(ok, np.array([0.0, -0.5]))
    with pytest.raises(SanitizeError, match="sample-domain"):
        san.check_samples(np.array([1.0, np.inf]), np.array([0.0, 0.0]))
    with pytest.raises(SanitizeError, match="sample-domain"):
        san.check_samples(np.array([0.5, 1.0]), np.array([0.6, 0.0]))


# ---------------------------------------------------------- repro artifact

def test_violation_writes_repro_artifact(tmp_path):
    san = FleetSanitizer("fleet", "prebaking",
                         scenario={"name": "fixture"},
                         artifact_dir=str(tmp_path))
    san.check_event(5.0, 1, 2)
    with pytest.raises(SanitizeError) as ei:
        san.check_event(4.0, 0, 3)
    path = ei.value.artifact_path
    assert path is not None and os.path.exists(path)
    payload = json.loads(open(path).read())
    assert payload["sanitizer_schema_version"] == 1
    assert payload["invariant"] == "event-order"
    assert payload["engine"] == "fleet"
    assert payload["method"] == "prebaking"
    assert payload["scenario"] == {"name": "fixture"}
    assert payload["event"] == {"t": 4.0, "kind": 0, "seq": 3}
    assert str(ei.value).startswith("[repro-san/event-order]")
    assert path in str(ei.value)


def test_artifact_name_is_content_addressed(tmp_path):
    paths = []
    for _ in range(2):
        san = FleetSanitizer("fleet", "warmswap",
                             artifact_dir=str(tmp_path))
        san.check_event(2.0, 0, 0)
        with pytest.raises(SanitizeError) as ei:
            san.check_event(1.0, 0, 1)
        paths.append(ei.value.artifact_path)
    assert paths[0] == paths[1]         # same violation, same digest


# ------------------------------------------- end-to-end seeded corruption

def test_runtime_books_corruption_is_caught(tmp_path, monkeypatch):
    """A books bug planted in the live ledger (admit drifts the incremental
    byte total) is caught by a sanitized run, with a repro artifact."""
    monkeypatch.setattr(sanitize_mod, "DEFAULT_ARTIFACT_DIR", str(tmp_path))
    orig_admit = CapacityLedger.admit

    def drifting_admit(self, key, nbytes, now, pinned=False):
        out = orig_admit(self, key, nbytes, now, pinned)
        self._used_bytes += 1
        return out

    monkeypatch.setattr(CapacityLedger, "admit", drifting_admit)
    with pytest.raises(SanitizeError, match="ledger-books") as ei:
        run(_scn("fleet_base"), smoke=True, sanitize=True)
    path = ei.value.artifact_path
    assert path is not None and os.path.exists(path)
    payload = json.loads(open(path).read())
    assert payload["invariant"] == "ledger-books"
    assert payload["scenario"]["name"] == "fleet_base"
