"""Trip-count-aware HLO analysis.

``compiled.cost_analysis()`` counts each ``while`` body exactly once, so any program
built on scan-over-layers (every model here) under-reports flops/bytes/collectives by
the trip count (verified: a 10-iteration scanned matmul reports 1/10th the unrolled
flops). This module re-derives the three roofline inputs by walking the optimized
(post-SPMD, per-device) HLO text:

  * every computation is parsed and every named value typed;
  * per computation: dot/convolution FLOPs (from result shape x contracting dims),
    an HBM-traffic proxy (operand + result bytes of top-level ops — a fusion counts
    its parameters/results once, matching the "stream each fusion operand once"
    model of HBM traffic), and collective output bytes by kind;
  * ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}`` in
    optimized HLO — body costs are multiplied by exactly that (nested scans compose:
    layer scan x attention-chunk scan x recurrence chunks);
  * fusion/call/reduce subcomputations contribute FLOPs and collectives (not bytes —
    their internals are on-chip).

Approximations (documented in EXPERIMENTS.md §Roofline): elementwise FLOPs ignored
(dot/conv dominate at these shapes); byte counts use full operand type sizes EXCEPT
for in-place slice ops — a fusion parameter consumed only by ``dynamic-slice`` is
charged the slice size, and a ``dynamic-update-slice``-rooted fusion is charged
2x the update size instead of the whole aliased buffer (matching XLA's in-place
update semantics; without this, every scan-carried KV-cache write would be charged
the full stacked cache per layer); all-reduce ring traffic is weighted 2x in the
collective term.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e3m4": 1, "f8e4m3": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "copy-start", "copy-done", "partition-id", "replica-id",
             "opt-barrier"}


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    return [(dt, [int(d) for d in dims.split(",") if d])
            for dt, dims in _SHAPE_RE.findall(type_str) if dt in _DTYPE_BYTES]


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Call:
    kind: str      # 'while' | 'sub'
    callee: str
    trips: int = 1


@dataclass
class _Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_count: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    calls: List[_Call] = field(default_factory=list)


def _split_computations(text: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{"):
                m = _COMP_HDR_RE.match(stripped)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    if stripped.startswith("ENTRY"):
                        entry = cur
        else:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def _dot_flops(op_type: str, rest: str, types: Dict[str, str]) -> float:
    res = _shape_dims(op_type)
    if not res:
        return 0.0
    n_res = 1
    for d in res[0][1]:
        n_res *= d
    operands = _OPERAND_RE.findall(rest.split(")", 1)[0])
    if not operands or operands[0] not in types:
        return 0.0
    lhs_dims = _shape_dims(types[operands[0]])
    if not lhs_dims:
        return 0.0
    contract = 1
    m = _CONTRACT_RE.search(rest)
    if m:
        for ci in (int(c) for c in m.group(1).split(",") if c):
            if ci < len(lhs_dims[0][1]):
                contract *= lhs_dims[0][1][ci]
    return 2.0 * n_res * contract


def _conv_flops(op_type: str, rest: str, types: Dict[str, str]) -> float:
    res = _shape_dims(op_type)
    operands = _OPERAND_RE.findall(rest.split(")", 1)[0])
    if not res or len(operands) < 2 or operands[1] not in types:
        return 0.0
    n_res = 1
    for d in res[0][1]:
        n_res *= d
    kd = _shape_dims(types[operands[1]])
    if not kd:
        return 0.0
    n_k = 1
    for d in kd[0][1]:
        n_k *= d
    out_ch = kd[0][1][-1] if kd[0][1] else 1
    return 2.0 * n_res * max(n_k // max(out_ch, 1), 1)


_COLLECT_TOP = False
_TOP_SINK: List[tuple] = []


def top_byte_contributors(text: str, n: int = 15) -> List[tuple]:
    """(bytes_with_trips, trips, opcode, name, type) — sorted desc, using the same
    in-place-aware accounting as analyze_module."""
    global _COLLECT_TOP, _TOP_SINK
    _COLLECT_TOP, _TOP_SINK = True, []
    try:
        analyze_module(text)
    finally:
        _COLLECT_TOP = False
    out = sorted(_TOP_SINK, reverse=True)[:n]
    _TOP_SINK = []
    return out


def analyze_module(text: str) -> Dict[str, object]:
    comps, entry = _split_computations(text)

    types: Dict[str, str] = {}
    raw_ops: Dict[str, List[Tuple[str, str, str, str]]] = {}
    for cname, lines in comps.items():
        ops = []
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            name, type_str, opcode, rest = m.groups()
            types[name] = type_str
            ops.append((name, type_str, opcode, rest))
        raw_ops[cname] = ops

    # --- in-place slice attribution helpers --------------------------------------
    def _fused_comp_info(comp: str):
        """(param_name->index, ops) for a fused computation."""
        params = {}
        for name, type_str, opcode, rest in raw_ops.get(comp, ()):
            if opcode == "parameter":
                m = re.match(r"(\d+)\)", rest)
                if m:
                    params[name] = int(m.group(1))
        return params, raw_ops.get(comp, ())

    def _fusion_bytes(rest: str, result_b: float, operand_names) -> float:
        """Byte cost of a fusion, charging slice-sized traffic for params that are
        only dynamic-sliced and DUS-rooted fusions (in-place updates)."""
        m = _CALLS_RE.search(rest)
        if not m or m.group(1) not in raw_ops:
            return result_b + sum(_type_bytes(types[o]) for o in operand_names
                                  if o in types)
        params, fops = _fused_comp_info(m.group(1))
        # follow unary pass-through chains (convert/copy/bitcast/reshape/transpose/
        # broadcast) so a DUS/DS consuming convert(param) still resolves to the param
        _PASS = {"convert", "copy", "bitcast", "reshape", "transpose", "broadcast"}
        alias = dict(params)                      # value name -> source param index
        local_types = dict(types)
        sliced_params = {}       # param index -> slice bytes
        dus_targets = set()      # param indices used as in-place update targets
        dus_update_b = 0.0
        has_dus = False
        for name, type_str, opcode, frest in fops:
            local_types[name] = type_str
            ops_in = _OPERAND_RE.findall(frest.split("),", 1)[0])
            if opcode in _PASS and len(ops_in) == 1 and ops_in[0] in alias:
                alias[name] = alias[ops_in[0]]
            if opcode == "dynamic-slice" and ops_in and ops_in[0] in alias:
                idx = alias[ops_in[0]]
                sliced_params[idx] = max(sliced_params.get(idx, 0.0),
                                         _type_bytes(type_str))
            if opcode in ("dynamic-update-slice", "scatter") and len(ops_in) >= 2:
                has_dus = True
                if ops_in[0] in alias:
                    dus_targets.add(alias[ops_in[0]])
                upd = ops_in[-1] if opcode == "scatter" else ops_in[1]
                dus_update_b += _type_bytes(local_types.get(upd, ""))
        total = 0.0
        for i, oname in enumerate(operand_names):
            if oname not in types:
                continue
            full = _type_bytes(types[oname])
            if i in sliced_params:
                total += min(sliced_params[i], full)
            elif has_dus and i in dus_targets:
                total += min(dus_update_b, full)     # read-modify region only
            else:
                total += full
        total += min(dus_update_b, result_b) if has_dus else result_b
        return total

    costs: Dict[str, _Cost] = {}
    for cname, ops in raw_ops.items():
        c = _Cost()
        for name, type_str, opcode, rest in ops:
            if opcode in _SKIP_OPS:
                continue
            base = opcode.replace("-start", "")
            result_b = _type_bytes(type_str)
            operand_names = [o for o in _OPERAND_RE.findall(rest.split("),", 1)[0])]
            operand_b = sum(_type_bytes(types[o]) for o in operand_names
                            if o in types)
            if opcode.endswith("-done"):
                continue
            if base in _COLLECTIVES:
                c.coll_bytes[base] += result_b
                c.coll_count[base] += 1
                c.bytes += result_b + operand_b
                continue
            if opcode == "while":
                body = _BODY_RE.search(rest)
                trips_m = _TRIP_RE.search(rest)
                trips = int(trips_m.group(1)) if trips_m else 1
                if body and body.group(1) in raw_ops:
                    c.calls.append(_Call("while", body.group(1), trips))
                continue
            if opcode == "dot":
                c.flops += _dot_flops(type_str, rest, types)
                c.bytes += result_b + operand_b
                continue
            if opcode == "convolution":
                c.flops += _conv_flops(type_str, rest, types)
                c.bytes += result_b + operand_b
                continue
            # subcomputations: flops/collectives propagate, bytes don't
            for m2 in _CALLS_RE.finditer(rest):
                if m2.group(1) in raw_ops:
                    c.calls.append(_Call("sub", m2.group(1), 1))
            bm = _BRANCH_RE.search(rest)
            if bm:
                for b in re.split(r",\s*", bm.group(1)):
                    b = b.strip().lstrip("%")
                    if b in raw_ops:
                        c.calls.append(_Call("sub", b, 1))
            if opcode == "fusion":
                c.bytes += _fusion_bytes(rest, result_b, operand_names)
            elif opcode == "dynamic-slice":
                c.bytes += 2 * result_b                    # read slice + write result
            elif opcode in ("dynamic-update-slice", "scatter"):
                upd = (operand_names[-1] if opcode == "scatter"
                       else operand_names[1]) if len(operand_names) > 1 else None
                ub = _type_bytes(types.get(upd, "")) if upd else result_b
                c.bytes += 3 * ub                          # read region+update, write
            else:
                c.bytes += result_b + operand_b
        costs[cname] = c

    from functools import lru_cache

    @lru_cache(maxsize=None)
    def total(cname: str) -> Tuple[float, float, tuple, tuple]:
        c = costs[cname]
        f, b = c.flops, c.bytes
        coll = dict(c.coll_bytes)
        cnt = dict(c.coll_count)
        for call in c.calls:
            cf, cb, ccoll, ccnt = total(call.callee)
            f += call.trips * cf
            if call.kind == "while":
                b += call.trips * cb
            for k, v in dict(ccoll).items():
                coll[k] = coll.get(k, 0.0) + call.trips * v
            for k, v in dict(ccnt).items():
                cnt[k] = cnt.get(k, 0) + call.trips * v
        return f, b, tuple(sorted(coll.items())), tuple(sorted(cnt.items()))

    # optional per-op attribution (profiling aid for the perf loop)
    if _COLLECT_TOP:
        mults: Dict[str, int] = {entry or "": 1}
        stack = [entry] if entry else []
        while stack:
            cn = stack.pop()
            for name, t, code, rest in raw_ops.get(cn, ()):
                if code == "while":
                    bm_ = _BODY_RE.search(rest)
                    tm_ = _TRIP_RE.search(rest)
                    if bm_ and bm_.group(1) in raw_ops:
                        mults[bm_.group(1)] = (mults.get(bm_.group(1), 0)
                                               + mults[cn] * (int(tm_.group(1))
                                                              if tm_ else 1))
                        stack.append(bm_.group(1))
        for cn, m in mults.items():
            for name, t, code, rest in raw_ops.get(cn, ()):
                if code in _SKIP_OPS or code.endswith("-done") or code == "while":
                    continue
                operand_names = [o for o in
                                 _OPERAND_RE.findall(rest.split("),", 1)[0])]
                rb = _type_bytes(t)
                if code == "fusion":
                    b = _fusion_bytes(rest, rb, operand_names)
                elif code in ("dynamic-update-slice", "scatter"):
                    upd = (operand_names[-1] if code == "scatter"
                           else operand_names[1]) if len(operand_names) > 1 else None
                    b = 3 * (_type_bytes(types.get(upd, "")) if upd else rb)
                elif code == "dynamic-slice":
                    b = 2 * rb
                else:
                    b = rb + sum(_type_bytes(types[o]) for o in operand_names
                                 if o in types)
                _TOP_SINK.append((m * b, m, code, name, t[:60]))

    if entry is None or entry not in costs:
        entry = max(raw_ops, key=lambda k: len(raw_ops[k])) if raw_ops else ""
    if not entry:
        return {"flops": 0.0, "bytes": 0.0, "collective_output_bytes": 0.0,
                "collective_ring_weighted_bytes": 0.0,
                "collective_bytes_by_kind": {}, "collective_count_by_kind": {},
                "n_computations": 0}

    f, b, coll_t, cnt_t = total(entry)
    coll = dict(coll_t)
    cnt = dict(cnt_t)
    total_coll = sum(coll.values())
    return {
        "flops": float(f),
        "bytes": float(b),
        "collective_bytes_by_kind": {k: float(v) for k, v in coll.items()},
        "collective_count_by_kind": {k: int(v) for k, v in cnt.items()},
        "collective_output_bytes": float(total_coll),
        "collective_ring_weighted_bytes": float(total_coll +
                                                coll.get("all-reduce", 0.0)),
        "n_computations": len(raw_ops),
    }
