"""The serving-correctness invariant: incremental decode (prefill + K single-token
steps) must reproduce the full-forward logits, across every architecture family —
including ring-buffer wraparound of sliding-window caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models.transformer import decode_step, forward, init_params

KEY = jax.random.PRNGKey(1)


def _fe(cfg, B):
    if cfg.frontend == "audio_frames":
        return jax.random.normal(KEY, (B, cfg.n_enc_positions, cfg.d_model)) * 0.02
    if cfg.frontend == "vision_patches":
        return jax.random.normal(KEY, (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_incremental_decode_matches_forward(arch):
    cfg = get_reduced(arch)
    params = init_params(KEY, cfg, jnp.float32)
    B, S, K = 2, 20, 5  # S+K exceeds the reduced window (16): exercises ring wrap
    toks = jax.random.randint(KEY, (B, S + K), 0, cfg.vocab_size)
    fe = _fe(cfg, B)
    F = cfg.n_frontend_tokens if cfg.frontend == "vision_patches" else 0

    full_logits, _, _ = forward(params, toks, cfg, frontend_embeds=fe)
    _, _, state = forward(params, toks[:, :S], cfg, frontend_embeds=fe,
                          make_state=True, state_len=F + S + K)
    for i in range(K):
        logits, state = decode_step(params, state, toks[:, S + i: S + i + 1], cfg)
    err = float(jnp.max(jnp.abs(logits - full_logits[:, F + S + K - 1])))
    assert err < 2e-3, f"{arch}: decode diverged from forward by {err}"


def test_decode_positions_advance_per_slot():
    cfg = get_reduced("qwen3_1_7b")
    params = init_params(KEY, cfg, jnp.float32)
    toks = jax.random.randint(KEY, (3, 8), 0, cfg.vocab_size)
    _, _, state = forward(params, toks, cfg, make_state=True, state_len=32)
    assert state["pos"].shape == (3,)
    _, state = decode_step(params, state, jnp.zeros((3, 1), jnp.int32), cfg)
    np.testing.assert_array_equal(np.asarray(state["pos"]), [9, 9, 9])
