"""Keep-alive math (§2.2), trace generation and the fleet simulator (§4.5),
including hypothesis property tests on the simulator's invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.keepalive import (
    argmax_rate,
    expected_cold_starts,
    p_no_invocation,
    worth_function_specific_tuning,
)
from repro.core.simulator import CostModel, memory_saving_fraction, quartile_latencies, simulate
from repro.core.traces import Trace, generate_traces, quartile_groups, sample_rates


# ---------------------------------------------------------------------------------
# §2.2 arrival math
# ---------------------------------------------------------------------------------

@given(st.floats(1e-5, 10.0), st.floats(1.0, 60.0))
@settings(max_examples=50, deadline=None)
def test_ecs_maximized_at_inverse_keepalive(lam, T):
    """Eq. 2 is maximized at λ* = 1/T (paper Fig. 1)."""
    star = argmax_rate(T)
    e_star = expected_cold_starts(star, T, 1440)
    assert expected_cold_starts(lam, T, 1440) <= e_star + 1e-9


def test_paper_headline_numbers():
    """>50% of fns see <1.4 cold starts/day at T=15min with rate<=0.001/min."""
    e = float(expected_cold_starts(0.001, 15.0, 1440))
    assert e < 1.45            # paper: "<1.4" for the >50% of fns BELOW 0.001/min
    assert float(expected_cold_starts(0.0009, 15.0, 1440)) < 1.4
    assert p_no_invocation(0.0, 15.0) == 1.0
    # frequent functions basically never cold start
    assert float(expected_cold_starts(10.0, 15.0, 1440)) < 1e-50


def test_tuning_economics():
    """Eq. 3: long-tail functions don't justify function-specific tuning."""
    assert not worth_function_specific_tuning(0.001, 15, 1440, benefit_per_cs=1.0,
                                              cost=10.0)
    assert worth_function_specific_tuning(1 / 15, 15, 1440, benefit_per_cs=1.0,
                                          cost=10.0)


# ---------------------------------------------------------------------------------
# Traces
# ---------------------------------------------------------------------------------

def test_rate_distribution_matches_azure_statistics():
    rates = sample_rates(20_000, seed=1)
    assert abs(np.median(rates) / 0.001 - 1) < 0.15       # median ~0.001/min
    assert abs(np.quantile(rates, 0.75) / 0.04 - 1) < 0.2  # P75 ~0.04/min


def test_traces_deterministic():
    t1 = generate_traces(5, horizon_min=1000, seed=42)
    t2 = generate_traces(5, horizon_min=1000, seed=42)
    for a, b in zip(t1, t2):
        assert np.array_equal(a.arrivals_min, b.arrivals_min)


def test_quartile_groups_partition():
    traces = generate_traces(40, horizon_min=100, seed=0)
    groups = quartile_groups(traces)
    total = sum(len(g) for g in groups.values())
    assert total == len(traces)


# ---------------------------------------------------------------------------------
# Simulator invariants
# ---------------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.floats(1.0, 30.0))
@settings(max_examples=20, deadline=None)
def test_cold_plus_warm_equals_invocations(seed, keepalive):
    from repro.core.keepalive import KeepAlivePolicy
    traces = generate_traces(8, horizon_min=2000, seed=seed)
    r = simulate(traces, "warmswap", CostModel.paper_table2(),
                 KeepAlivePolicy(keepalive))
    n_total = sum(len(t.arrivals_min) for t in traces)
    assert r.n_cold + r.n_warm == n_total == r.n_invocations
    assert r.n_cold >= sum(1 for t in traces if len(t.arrivals_min) > 0)


def test_longer_keepalive_fewer_cold_starts():
    from repro.core.keepalive import KeepAlivePolicy
    traces = generate_traces(20, horizon_min=5000, seed=3)
    cm = CostModel.paper_table2()
    cold = [simulate(traces, "baseline", cm, KeepAlivePolicy(T)).n_cold
            for T in (5.0, 15.0, 60.0)]
    assert cold[0] >= cold[1] >= cold[2]


def test_fig7_reproduction():
    """WarmSwap beats Prebaking on latency and saves ~88-89% memory for 10 fns
    sharing one image (paper §4.5 headline)."""
    traces = generate_traces(10, horizon_min=2 * 7 * 24 * 60, seed=0)
    cm = CostModel.paper_table2()
    rw = simulate(traces, "warmswap", cm)
    rp = simulate(traces, "prebaking", cm)
    rb = simulate(traces, "baseline", cm)
    assert rw.avg_latency_s <= rp.avg_latency_s <= rb.avg_latency_s
    saving = memory_saving_fraction(rw, rp)
    assert 0.85 < saving < 0.92
    ql = quartile_latencies(traces, rw)
    assert set(ql) == {"lowest", "25-50%", "50-75%", "highest"}
    # latency decreases as invocation rate rises (more warm starts), Fig. 7-left
    assert ql["highest"] <= ql["lowest"] + 1e-9
