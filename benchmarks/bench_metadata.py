"""Paper Table 3: per-image metadata size vs full image size — the asymmetry that
makes the communication phase cheap and the page server necessary."""
from __future__ import annotations

from typing import Dict

from benchmarks.common import build_fleet, emit, save_json


def run() -> Dict:
    from repro.core import workloads as wl
    mgr, reg, orch = build_fleet()
    rows: Dict = {}
    for image_id in ["py-base", "model-tiny", "model-small", "model-medium"]:
        img = mgr._ensure_live(image_id)
        rows[image_id] = {
            "metadata_bytes": img.metadata_bytes,
            "image_bytes": img.image_bytes,
            "payload_bytes": img.metadata.page_table.nbytes_payload,
            "n_pages": img.metadata.page_table.n_pages,
            "ratio": img.image_bytes / max(img.metadata_bytes, 1),
        }
        emit(f"metadata/{image_id}", img.metadata_bytes,
             f"image={img.image_bytes/1e6:.1f}MB ratio=x{rows[image_id]['ratio']:.0f}")
    save_json("bench_metadata", rows)
    return rows


if __name__ == "__main__":
    run()
