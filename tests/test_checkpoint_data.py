"""Checkpointer (atomicity, integrity, GC, resume) + data pipeline properties."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointConfig, Checkpointer, latest_step
from repro.configs import get_reduced
from repro.data import DataConfig, SyntheticTokenPipeline


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (16, 16)),
            "b": {"x": jax.random.normal(k, (4,)).astype(jnp.bfloat16),
                  "n": jnp.int32(7)}}


def test_checkpoint_roundtrip_exact():
    with tempfile.TemporaryDirectory() as tmp:
        ck = Checkpointer(CheckpointConfig(tmp, async_save=False))
        tree = _tree()
        ck.save(5, {"params": tree})
        out = ck.restore(None, {"params": tree})
        assert int(out["__manifest__"]["step"]) == 5
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out["params"])):
            assert np.array_equal(np.asarray(a), np.asarray(b))
            assert np.asarray(a).dtype == np.asarray(b).dtype


def test_checkpoint_gc_keeps_last_k():
    with tempfile.TemporaryDirectory() as tmp:
        ck = Checkpointer(CheckpointConfig(tmp, keep_last=2, async_save=False))
        for s in (1, 2, 3, 4):
            ck.save(s, {"params": _tree()})
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp)
                       if d.startswith("step_"))
        assert steps == [3, 4]
        assert latest_step(tmp) == 4


def test_checkpoint_async_and_wait():
    with tempfile.TemporaryDirectory() as tmp:
        ck = Checkpointer(CheckpointConfig(tmp, async_save=True))
        ck.save(1, {"params": _tree()})
        ck.wait()
        assert latest_step(tmp) == 1


def test_checkpoint_integrity_detection():
    with tempfile.TemporaryDirectory() as tmp:
        ck = Checkpointer(CheckpointConfig(tmp, async_save=False))
        ck.save(1, {"params": _tree()})
        # corrupt one leaf on disk
        path = os.path.join(tmp, "step_1", "params_0.npy")
        arr = np.load(path)
        arr_flat = arr.reshape(-1).copy()
        arr_flat[0] += 1
        np.save(path, arr_flat.reshape(arr.shape))
        with pytest.raises(IOError, match="crc"):
            ck.restore(None, {"params": _tree()})


def test_no_partial_checkpoint_visible():
    """Atomicity: only fully-written step dirs appear (tmp dirs are invisible)."""
    with tempfile.TemporaryDirectory() as tmp:
        os.makedirs(os.path.join(tmp, "step_9.tmp"))  # simulated crash mid-save
        assert latest_step(tmp) is None


# ---------------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------------

@given(st.integers(0, 1000), st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_data_deterministic_property(seed, step):
    cfg = get_reduced("qwen3_1_7b")
    d = DataConfig(global_batch=4, seq_len=16, seed=seed)
    b1 = SyntheticTokenPipeline.batch_at(cfg, d, step)
    b2 = SyntheticTokenPipeline.batch_at(cfg, d, step)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < cfg.vocab_size


def test_host_sharding_partitions_batch():
    """Different hosts generate different slices; each is deterministic."""
    cfg = get_reduced("qwen3_1_7b")
    d = DataConfig(global_batch=8, seq_len=16, seed=1)
    h0 = SyntheticTokenPipeline.batch_at(cfg, d, 3, host_index=0, host_count=2)
    h1 = SyntheticTokenPipeline.batch_at(cfg, d, 3, host_index=1, host_count=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_pipeline_prefetch_streams():
    cfg = get_reduced("qwen3_1_7b")
    d = DataConfig(global_batch=2, seq_len=8, seed=0)
    pipe = SyntheticTokenPipeline(cfg, d)
    steps = []
    for _ in range(4):
        s, batch = next(pipe)
        steps.append(s)
        assert batch["tokens"].shape == (2, 8)
    pipe.close()
    assert steps == [0, 1, 2, 3]
    # prefetched batches equal random-access batches
    ref = SyntheticTokenPipeline.batch_at(cfg, d, 2)
    pipe2 = SyntheticTokenPipeline(cfg, d)
    for _ in range(3):
        s, b = next(pipe2)
    pipe2.close()
    assert np.array_equal(b["tokens"], ref["tokens"])


def test_vlm_batch_shapes():
    cfg = get_reduced("internvl2_1b")
    d = DataConfig(global_batch=2, seq_len=16, seed=0)
    b = SyntheticTokenPipeline.batch_at(cfg, d, 0)
    assert b["tokens"].shape == (2, 16 - cfg.n_frontend_tokens)
    assert b["patches"].shape == (2, cfg.n_frontend_tokens, cfg.d_model)
