"""Train a small LM end-to-end with the full substrate (a few hundred steps on CPU):
deterministic pipeline, AdamW + cosine schedule, async checkpoints, and an injected
mid-run failure that the supervisor rolls back transparently.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointConfig
from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models.api import make_train_step
from repro.models.transformer import init_params
from repro.optim import adamw_init
from repro.runtime import InjectedFailure, SupervisorConfig, TrainSupervisor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config("fnbench_tiny")
    data = DataConfig(global_batch=args.batch, seq_len=args.seq, seed=0)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    opt = adamw_init(params)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n/1e6:.2f}M params, {args.steps} steps")

    step_fn = jax.jit(make_train_step(cfg, peak_lr=1e-3, warmup_steps=20,
                                      total_steps=args.steps, remat="none"),
                      donate_argnums=(0, 1))
    with tempfile.TemporaryDirectory() as tmp:
        sup = TrainSupervisor(
            SupervisorConfig(checkpoint_every=50,
                             checkpoint=CheckpointConfig(tmp)),
            step_fn,
            lambda s: {k: jnp.asarray(v) for k, v in
                       SyntheticTokenPipeline.batch_at(cfg, data, s).items()})
        losses = []
        params, opt, hist = sup.run(
            params, opt, 0, args.steps,
            fail_at={args.steps // 2: InjectedFailure("simulated node failure")},
            on_metrics=lambda s, m: (
                losses.append(m["loss"]),
                print(f"[train] step {s:4d} loss={m['loss']:.4f} "
                      f"lr={m['lr']:.2e}") if s % 25 == 0 else None))
    print(f"[train] loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}; "
          f"failures recovered: {sup.restores}")
    assert hist[-1]["loss"] < hist[0]["loss"], "training must make progress"


if __name__ == "__main__":
    main()
