from repro.data.pipeline import DataConfig, SyntheticTokenPipeline, make_batch_specs

__all__ = ["DataConfig", "SyntheticTokenPipeline", "make_batch_specs"]
