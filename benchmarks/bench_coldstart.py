"""Paper Figs. 3, 5, 6: cold-start latency, breakdown, and warm starts, for the
seven FunctionBench-analogue workloads, Baseline vs WarmSwap (bulk restore).

Reports BOTH comparisons (the assignment requires the paper-faithful baseline and the
beyond-paper version separately):
  * ``dep_speedup_paper``  — dependency LOADING only: baseline disk-load+deserialize
    vs WarmSwap communication+migration (both sides excluding XLA compile) — the
    apples-to-apples analogue of the paper's 2.2-3.2x dependency-loading gain;
  * ``dep_speedup_full``   — including the compile-cache benefit of carrying
    pre-built executables in the dependency image (beyond-paper extension).
"""
from __future__ import annotations

import sys
from typing import Dict

from benchmarks.common import build_fleet, emit, median, save_json

FUNCTIONS = ["helloworld", "json_dumps_load", "pyaes", "chameleon",
             "lr_serving", "cnn_serving", "rnn_serving"]
ITERS = 3


def run() -> Dict:
    from repro.core import workloads as wl
    mgr, reg, orch = build_fleet(FUNCTIONS)
    rows = {}
    for fn in FUNCTIONS:
        b_times, w_times, warm_b, warm_w = [], [], [], []
        breakdown_b = breakdown_w = None
        for _ in range(ITERS):
            inst_b, tb = orch.cold_start_baseline(fn)
            inst_w, tw = orch.cold_start_warmswap(fn)
            b_times.append(tb)
            w_times.append(tw)
            req = wl.WORKLOADS[fn].request_builder()
            warm_b.append(min(inst_b.invoke(req)[1] for _ in range(3)))
            warm_w.append(min(inst_w.invoke(req)[1] for _ in range(3)))
            breakdown_b, breakdown_w = tb.as_dict(), tw.as_dict()
        tb_med = median([t.total for t in b_times])
        tw_med = median([t.total for t in w_times])
        dep_base_load = median([t.dependency_load for t in b_times])
        dep_base_full = median([t.dependency_init for t in b_times])
        dep_ws = median([t.communication + t.migration for t in w_times])
        rows[fn] = {
            "image": wl.WORKLOADS[fn].image_id,
            "cold_baseline_s": tb_med,
            "cold_warmswap_s": tw_med,
            "cold_speedup": tb_med / max(tw_med, 1e-9),
            "dep_speedup_paper": dep_base_load / max(dep_ws, 1e-9),
            "dep_speedup_full": dep_base_full / max(dep_ws, 1e-9),
            "warm_baseline_s": median(warm_b),
            "warm_warmswap_s": median(warm_w),
            "breakdown_baseline": breakdown_b,
            "breakdown_warmswap": breakdown_w,
        }
        emit(f"coldstart/{fn}/baseline", tb_med * 1e6,
             f"dep_init={dep_base_full*1e3:.1f}ms")
        emit(f"coldstart/{fn}/warmswap", tw_med * 1e6,
             f"x{rows[fn]['cold_speedup']:.2f} dep_paper=x"
             f"{rows[fn]['dep_speedup_paper']:.2f} dep_full=x"
             f"{rows[fn]['dep_speedup_full']:.2f}")
        emit(f"warmstart/{fn}", rows[fn]["warm_warmswap_s"] * 1e6,
             f"baseline={rows[fn]['warm_baseline_s']*1e6:.0f}us")
    save_json("bench_coldstart", rows)
    return rows


if __name__ == "__main__":
    run()
