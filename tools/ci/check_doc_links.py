#!/usr/bin/env python
"""Markdown link check: fails on dangling intra-repo links in README.md and
docs/*.md. Runs locally and in CI's docs job.

    python tools/ci/check_doc_links.py [README.md docs/*.md ...]
"""
import glob
import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def main(*files):
    files = list(files) or ["README.md"] + sorted(glob.glob("docs/*.md"))
    dangling = []
    for f in files:
        base = os.path.dirname(f)
        for target in LINK_RE.findall(open(f).read()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = os.path.normpath(os.path.join(base, target.split("#")[0]))
            if not os.path.exists(path):
                dangling.append((f, target))
    if dangling:
        for f, t in dangling:
            print(f"dangling link in {f}: {t}")
        return 1
    print(f"ok: {len(files)} files link-checked")
    return 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
