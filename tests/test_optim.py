"""Optimizer, schedule, and gradient-compression correctness."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress_gradients,
    cosine_schedule,
    decompress_gradients,
    global_norm,
    init_error_feedback,
)


def test_adamw_optimizes_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    cfg = AdamWConfig(weight_decay=0.0)
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw_update(grads, opt, params, jnp.float32(0.05), cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_grad_clipping():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, m = adamw_update(huge, opt, params, jnp.float32(0.1),
                           AdamWConfig(clip_norm=1.0))
    assert float(m["grad_norm"]) > 1e5
    assert float(m["clip_scale"]) < 1e-4


def test_cosine_schedule_shape():
    s = [float(cosine_schedule(t, peak_lr=1.0, warmup_steps=10, total_steps=100))
         for t in range(100)]
    assert s[0] < s[5] < s[9]                      # warmup rises
    assert abs(s[10] - 1.0) < 0.02                 # peak after warmup
    assert s[99] < 0.2                             # decays toward final_frac
    assert all(x >= 0 for x in s)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_compression_error_feedback_property(seed):
    """Quantization residual is carried, so the two-step compressed sum tracks the
    exact sum to within one quantization step."""
    rng = np.random.default_rng(seed)
    g1 = {"w": jnp.asarray(rng.standard_normal(64), jnp.float32)}
    g2 = {"w": jnp.asarray(rng.standard_normal(64), jnp.float32)}
    ef = init_error_feedback(g1)
    c1, ef = compress_gradients(g1, ef)
    d1 = decompress_gradients(c1)
    c2, ef = compress_gradients(g2, ef)
    d2 = decompress_gradients(c2)
    exact = np.asarray(g1["w"] + g2["w"])
    approx = np.asarray(d1["w"] + d2["w"] + ef["w"])
    np.testing.assert_allclose(approx, exact, atol=1e-4)


def test_compression_bytes_shrink():
    g = {"w": jnp.ones((1024,), jnp.float32)}
    c, _ = compress_gradients(g, init_error_feedback(g))
    assert c["q"]["w"].dtype == jnp.int8           # 4x smaller than f32 over the wire


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
