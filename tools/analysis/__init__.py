"""repro-lint: project-specific static analysis for the determinism,
lock-discipline, shared-state, and spec/registry contracts.

    python -m tools.analysis [--baseline FILE] [--fix-suggestions] paths...

Checkers (each a module exposing ``check(SourceFile) -> List[Finding]``; the
spec checker works on JSON files instead):

  =================  ======================================================
  checker            contract it enforces
  =================  ======================================================
  determinism        results are a pure function of (spec, seed): no
                     unseeded RNG, wall clocks, hash()-order, set-order
                     leaks, or undeclared env reads in simulation code
  lock-discipline    ``# guarded-by:`` attributes only touched inside
                     ``with self.<lock>`` (the PR-2 race shape)
  shared-state       no mutable default args, module-level mutable state,
                     or stale/loop-variable closure captures (PR-1/PR-4)
  spec-registry      every scenario component {name, kwargs} matches the
                     registered factory's signature
  =================  ======================================================

Findings diff against ``tools/analysis/baseline.json`` — pre-existing
grandfathered violations pass, new ones fail. Catalog, annotation grammar,
and baseline workflow: docs/ANALYSIS.md.
"""
from __future__ import annotations

from tools.analysis.findings import (Finding, diff_baseline, findings_json,
                                     load_baseline, write_baseline)

__all__ = ["Finding", "diff_baseline", "findings_json", "load_baseline",
           "write_baseline", "run_analysis", "PY_CHECKERS"]


def run_analysis(paths, checkers=None):
    """Run the named ``checkers`` (default: all) over ``paths``; returns the
    flat finding list in (path, line) order. Programmatic twin of the CLI."""
    from tools.analysis.__main__ import run_analysis as _impl
    return _impl(paths, checkers)
