"""Typed discrete-event core for the fleet simulator (``core/fleet.py``).

The fleet engine is a single time-ordered queue of four event kinds plus the
(pre-sorted, vectorized) merged arrival stream.  Arrivals never enter the
heap — ``fleet.py`` merges the sorted arrival arrays against the heap head —
so per-event work stays O(log n) no matter how many invocations a trace has.

Tie-breaking at equal timestamps is load-bearing and encoded in the
``EventKind`` integer values:

  1. ``INSTANCE_FREE``    — a completing request frees its instance *before*
     anything else at that instant, so an arrival (or queued request) at
     exactly the completion time sees an idle instance (warm, no wait);
  2. ``PREWARM_SPAWN``    — a predictive pre-warm lands before the arrival it
     anticipates;
  3. (arrivals)           — merged in here from the sorted trace arrays;
  4. ``KEEPALIVE_EXPIRY`` — an arrival at exactly the expiry instant is still
     warm (``simulate()``'s ``t <= expiry`` contract).

Disruption events (``core/disruption.py``) rank strictly AFTER every
fair-weather kind at the same instant — new kinds are **appended** at ranks
>= 4 so the documented [0, 1, 2, 3] tie-break above never renumbers:

  5. ``WORKER_FAIL``      — a worker dying at ``t`` lets arrivals and
     expiries at exactly ``t`` resolve first (a request arriving the instant
     a worker fails is served or queued under fair weather, then disrupted);
  6. ``WORKER_RECOVER``   — likewise, and a same-instant fail+recover pair
     resolves fail-first (it was authored as a downtime of zero);
  7. ``CACHE_FLUSH``      — an eviction storm at ``t`` evicts after every
     same-instant cold start already admitted its image.

Within one (time, kind) bucket, insertion order wins (FIFO).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from enum import IntEnum
from typing import Any, Optional, Tuple


class EventKind(IntEnum):
    """Heap tie-break order at equal timestamps (see module docstring).

    Ranks [0, 3] are the documented fair-weather tie-break and are pinned by
    ``tests/test_sim_properties.py``; new kinds must be appended at >= 4.
    """
    INSTANCE_FREE = 0
    PREWARM_SPAWN = 1
    ARRIVAL = 2            # never heaped; used as the merge-comparison rank
    KEEPALIVE_EXPIRY = 3
    WORKER_FAIL = 4        # disruption: kill a worker (core/disruption.py)
    WORKER_RECOVER = 5     # disruption: the worker returns, pool empty
    CACHE_FLUSH = 6        # disruption: fleet-wide shared-image eviction storm


@dataclass(frozen=True, slots=True)
class Event:
    time: float            # minutes
    kind: EventKind
    payload: Any = None


class EventQueue:
    """Min-heap of events, ordered by (time, kind, insertion seq).

    Payloads are never compared: the insertion sequence number is a unique
    tie-break, so arbitrary (unorderable) payload objects are fine.

    Heap records are plain ``(time, kind_int, seq, payload)`` tuples — the
    fleet engine's hot loop uses :meth:`pop_raw` (and reads :attr:`heap`
    directly for its merge comparison) so a million-event run never
    constructs an :class:`Event` or an ``EventKind`` per pop; :meth:`pop`
    wraps the same record for callers that want the typed view.
    """

    __slots__ = ("heap", "_seq")

    def __init__(self) -> None:
        #: The underlying heap list of ``(time, kind_int, seq, payload)``
        #: records; read-only for callers (the engine peeks ``heap[0]``).
        self.heap: list = []
        self._seq = itertools.count()

    def push(self, time: float, kind: int, payload: Any = None) -> None:
        """Schedule an event.

        Args:
            time: firing time in simulation **minutes**.
            kind: event type (an :class:`EventKind` or its integer value);
                the integer is the equal-time tie-break rank (see the
                module docstring).
            payload: opaque data handed back on :meth:`pop`; never compared.
        """
        heapq.heappush(self.heap, (time, int(kind), next(self._seq), payload))

    def pop(self) -> Event:
        """Remove and return the earliest event (by time, then kind, then
        insertion order). Raises ``IndexError`` when empty."""
        time, kind, _, payload = heapq.heappop(self.heap)
        return Event(time, EventKind(kind), payload)

    def pop_raw(self) -> Tuple[float, int, int, Any]:
        """Remove and return the earliest raw heap record
        ``(time_minutes, kind_int, seq, payload)`` without wrapping it —
        the allocation-free form the fleet engine's event loop consumes."""
        return heapq.heappop(self.heap)

    def peek_key(self) -> Optional[Tuple[float, int]]:
        """``(time_minutes, kind_rank)`` of the earliest event, or ``None``
        when empty — the comparison key the fleet engine merges the sorted
        arrival stream against."""
        if not self.heap:
            return None
        return (self.heap[0][0], self.heap[0][1])

    def __len__(self) -> int:
        return len(self.heap)

    def __bool__(self) -> bool:
        return bool(self.heap)
