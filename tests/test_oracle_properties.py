"""Oracle-dominance property suite: the hindsight floor (core/oracle.py)
lower-bounds EVERY online policy on every trace, under identical cost models
and constraints — the invariant the CI bench gate (tools/ci/check_bench.py)
asserts over the tournament artifact, fenced here at tier-1 scale:

  * hypothesis fuzz over randomized fleet configs x every registered prewarm
    x placement policy (disruption schedules included), asserting pointwise
    dominance of the sorted sample vectors — which implies dominance of the
    total, the mean, and every percentile;
  * a shrunken-grid sweep over EVERY checked-in fleet scenario spec
    (``benchmarks/scenarios/*.json``, disruption specs included) x every
    registered prewarm x placement combo — the full-scale specs the bench
    audit skips past its arrival cap (``bench_policies.AUDIT_MAX_ARRIVALS``)
    are covered here at a trimmed horizon;
  * the golden oracle fixture (tests/data/golden_oracle_small.json): a
    hand-derivable 20-request case whose floor both engines ACHIEVE exactly
    in their degenerate configurations, compared ``==`` per float;
  * unit properties of the floor arithmetic, the gap report, and the
    keep-alive frontier (report-only — never the dominance gate).

Runs under real `hypothesis` when installed; otherwise tests/conftest.py
substitutes the deterministic seeded-fuzz shim (tests/_hypothesis_fallback.py).
Normative semantics: docs/SIMULATION.md, "Oracle and disruption semantics".
"""
import glob
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.costmodel import PAGE_COST_MODELS
from repro.core.disruption import DISRUPTIONS
from repro.core.fleet import FleetConfig, _simulate_fleet_impl
from repro.core.fleet_vec import simulate_fleet_vec
from repro.core.keepalive import PREWARM_POLICIES
from repro.core.oracle import (hindsight_floor, gap_report, idle_bytes_for,
                               keepalive_frontier, min_cold_latency_s,
                               oracle_from_scenario)
from repro.core.scenario import COST_MODELS, RunOverrides, Scenario, run
from repro.core.simulator import CostModel, method_cold_latency_s
from repro.core.traces import TRACE_GENERATORS, Trace, generate_fleet_traces
from repro.serving.scheduler import PLACEMENTS

DATA = os.path.join(os.path.dirname(__file__), "data")
SCENARIOS_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                             "scenarios")
CM = CostModel.paper_table2()

#: Every registered online policy — the dominance claim is quantified over
#: these, so registering a new policy automatically widens the suite.
PREWARMS = sorted(PREWARM_POLICIES.names())
PLACES = sorted(PLACEMENTS.names())


def assert_dominated(oracle, r, label=""):
    """The oracle-dominance invariant, asserted sample-by-sample.

    Sorting both vectors compares k-th order statistics; pointwise dominance
    there implies dominance of the total, the mean, and every percentile
    (np.percentile interpolates the sorted samples monotonically). Exact
    (no epsilon): the floor is built from the same float constants the
    engines charge, never from derived arithmetic that could round past.
    """
    assert r.n_invocations == oracle.n_invocations, label
    got = np.sort(np.asarray(r.latency_samples_s, np.float64))
    floor = np.sort(oracle.latency_samples_s)
    bad = np.flatnonzero(got < floor)
    assert bad.size == 0, \
        f"{label}: engine sample {bad[0] if bad.size else 0} undercut the " \
        f"floor: {got[bad[0]]!r} < {floor[bad[0]]!r}"
    gaps = gap_report(oracle, r)
    assert gaps["total_gap_s"] >= 0.0, f"{label}: {gaps}"
    assert gaps["p99_gap_s"] >= 0.0, f"{label}: {gaps}"
    # one unavoidable cold per function also bounds the engine's cold count
    assert r.n_cold >= oracle.n_cold, label


# ---------------------------------------------------------------------------------
# Hypothesis fuzz: random configs x every registered prewarm x placement
# ---------------------------------------------------------------------------------

@st.composite
def _oracle_cases(draw):
    return {
        "n_functions": draw(st.integers(1, 8)),
        "n_images": draw(st.integers(1, 3)),
        "horizon_min": draw(st.sampled_from([60.0, 240.0])),
        "total_rate_per_min": draw(st.floats(0.5, 20.0)),
        "seed": draw(st.integers(0, 10_000)),
        "method": draw(st.sampled_from(["warmswap", "prebaking", "baseline"])),
        "n_workers": draw(st.sampled_from([1, 2, 4])),
        "cap": draw(st.sampled_from([None, 1, 2])),
        "keep_alive_min": draw(st.floats(0.5, 20.0)),
        "prewarm": draw(st.sampled_from(PREWARMS)),
        "placement": draw(st.sampled_from(PLACES)),
        "disruption": draw(st.sampled_from([None, "churn", "preempt",
                                            "storm"])),
    }


def _fleet_kwargs(case):
    disruption = None
    if case["disruption"] is not None:
        disruption = DISRUPTIONS.build(
            case["disruption"], n_workers=case["n_workers"],
            horizon_min=case["horizon_min"],
            **({"mean_uptime_min": 40.0, "downtime_min": 5.0,
                "seed": case["seed"]} if case["disruption"] == "churn" else {}))
    return dict(n_workers=case["n_workers"], placement=case["placement"],
                prewarm=case["prewarm"],
                max_instances_per_fn=case["cap"],
                keep_alive_min=case["keep_alive_min"],
                disruption=disruption)


@settings(max_examples=25, deadline=None)
@given(_oracle_cases())
def test_oracle_dominates_fuzzed_configs(case):
    """No fuzzed prewarm x placement x disruption combo, in either engine,
    produces a latency vector below the hindsight floor."""
    traces = generate_fleet_traces(
        n_functions=case["n_functions"], horizon_min=case["horizon_min"],
        seed=case["seed"], n_images=case["n_images"], rate_model="zipf",
        total_rate_per_min=case["total_rate_per_min"])
    oracle = hindsight_floor(traces, case["method"], CM)
    for impl in (_simulate_fleet_impl, simulate_fleet_vec):
        r = impl(traces, case["method"], CM, FleetConfig(**_fleet_kwargs(case)))
        assert_dominated(
            oracle, r,
            label=f"{impl.__name__}/{case['method']}/{case['prewarm']}/"
                  f"{case['placement']}/{case['disruption']}")


@settings(max_examples=10, deadline=None)
@given(_oracle_cases())
def test_oracle_is_deterministic(case):
    """Same traces, same floor — bit-identical samples on repeat."""
    traces = generate_fleet_traces(
        n_functions=case["n_functions"], horizon_min=case["horizon_min"],
        seed=case["seed"], n_images=case["n_images"], rate_model="zipf",
        total_rate_per_min=case["total_rate_per_min"])
    a = hindsight_floor(traces, case["method"], CM)
    b = hindsight_floor(traces, case["method"], CM)
    assert np.array_equal(a.latency_samples_s, b.latency_samples_s)
    assert a.total_latency_s == b.total_latency_s


# ---------------------------------------------------------------------------------
# Every checked-in fleet spec x every registered prewarm x placement
# ---------------------------------------------------------------------------------

def _fleet_spec_names():
    out = []
    for path in sorted(glob.glob(os.path.join(SCENARIOS_DIR, "*.json"))):
        if Scenario.from_file(path).engine in ("fleet", "fleet_vec"):
            out.append(os.path.splitext(os.path.basename(path))[0])
    return out


#: Every spec runs its full policy grid at a trimmed horizon (12 combos x
#: methods adds up) — this is the tier-1 coverage
#: ``benchmarks/bench_policies.py`` delegates to when its audit caps out
#: (``AUDIT_MAX_ARRIVALS``); the full scale runs in the bench job. The big
#: replay specs trim harder: their function counts dominate.
_GRID_TRIM_DEFAULT = {"traces.kwargs.horizon_min": 360}
_GRID_TRIMS = {
    "azure_scale": {"traces.kwargs.horizon_min": 120},
    "azure_scale_xl": {"traces.kwargs.horizon_min": 30},
}


@pytest.mark.parametrize("name", _fleet_spec_names())
def test_oracle_dominates_every_spec_policy_grid(name):
    """For one checked-in spec (smoke-scaled, disruption axes kept): run the
    FULL registered prewarm x placement grid through the spec's own engine
    and assert the floor under every cell. Traces / cost / page model are
    resolved once and shared, so every cell is measured against one floor."""
    scn = Scenario.from_file(
        os.path.join(SCENARIOS_DIR, f"{name}.json")).smoke_scaled()
    scn = scn.with_overrides(_GRID_TRIMS.get(name, _GRID_TRIM_DEFAULT))
    traces = TRACE_GENERATORS.build(scn.traces.name, **scn.traces.kwargs)
    cost = COST_MODELS.build(scn.cost.name, **scn.cost.kwargs)
    page = None
    if scn.page_cost is not None:
        page = PAGE_COST_MODELS.build(scn.page_cost.name, cost=cost,
                                      **scn.page_cost.kwargs)
    oracle = {m: hindsight_floor(traces, m, cost, page) for m in scn.methods}
    ov = RunOverrides(traces=traces, cost=cost, page_cost=page)
    for prewarm in PREWARMS:
        for placement in PLACES:
            cell = scn.with_overrides({
                "prewarm": {"name": prewarm, "kwargs": {}},
                "placement": {"name": placement, "kwargs": {}},
            })
            res = run(cell, overrides=ov)
            for m, r in res.raw.items():
                assert_dominated(oracle[m], r,
                                 label=f"{name}/{prewarm}/{placement}/{m}")


# ---------------------------------------------------------------------------------
# Golden fixture: a floor both engines achieve exactly
# ---------------------------------------------------------------------------------

def _load_golden():
    doc = json.load(open(os.path.join(DATA, "golden_oracle_small.json")))
    traces = [Trace(d["fn_index"], d["rate_per_min"],
                    np.array(d["arrivals_min"], np.float64),
                    image_id=d["image_id"])
              for d in doc["traces"]]
    return doc, traces


def test_golden_oracle_fixture_exact():
    """The oracle reproduces the hand-derived fixture numbers ``==`` per
    float: 2 functions' first arrivals at 0.89 + 0.5 = 1.39 s, the other 18
    requests at 0.004 s."""
    doc, traces = _load_golden()
    want = doc["expected"]
    o = hindsight_floor(traces, doc["method"], CostModel(**doc["cost_kwargs"]))
    assert (o.n_invocations, o.n_cold, o.n_warm) == \
        (want["n_invocations"], want["n_cold"], want["n_warm"])
    assert o.min_cold_s == want["min_cold_s"]
    assert o.warm_s == want["warm_s"]
    assert o.total_latency_s == want["total_latency_s"]
    assert list(o.latency_samples_s) == want["latency_samples_s"]
    assert o.latency_percentiles() == want["latency_percentiles_s"]


@pytest.mark.parametrize("engine", ["fleet", "fleet_vec"])
@pytest.mark.parametrize("page_name", [None, "degenerate"])
def test_golden_oracle_floor_achieved_by_engines(engine, page_name):
    """Both engines ACHIEVE the fixture's floor exactly — in the scalar
    configuration and under the degenerate page model (whose transfer terms
    are zero by contract) — so the bound is tight, not merely valid."""
    doc, traces = _load_golden()
    want = doc["expected"]
    cost = CostModel(**doc["cost_kwargs"])
    page = (PAGE_COST_MODELS.build(page_name, cost=cost)
            if page_name else None)
    impl = simulate_fleet_vec if engine == "fleet_vec" else _simulate_fleet_impl
    r = impl(traces, doc["method"], cost,
             FleetConfig(page_cost=page, **doc["fleet"]))
    assert (r.n_cold, r.n_warm) == (want["n_cold"], want["n_warm"])
    assert float(r.total_latency_s) == want["total_latency_s"]
    assert list(r.latency_samples_s) == want["latency_samples_s"]
    assert float(np.abs(r.queue_wait_s).max()) == 0.0


def test_golden_fixture_is_hand_derivable():
    """The fixture stays small and derivable on paper: <= 20 requests,
    2 workers, and its stored constants recompose from the cost kwargs."""
    doc, traces = _load_golden()
    ck = doc["cost_kwargs"]
    assert sum(len(t.arrivals_min) for t in traces) <= 20
    assert doc["fleet"]["n_workers"] == 2
    assert doc["expected"]["min_cold_s"] == \
        ck["cold_warmswap_s"] + ck["container_s"]
    assert doc["expected"]["warm_s"] == ck["warm_s"]
    assert doc["expected"]["n_cold"] == len(traces)


# ---------------------------------------------------------------------------------
# Floor arithmetic and report units
# ---------------------------------------------------------------------------------

def test_min_cold_formulas():
    assert min_cold_latency_s("warmswap", CM) == \
        method_cold_latency_s(CM, "warmswap")
    assert min_cold_latency_s("prebaking", CM) == \
        method_cold_latency_s(CM, "prebaking")
    assert min_cold_latency_s("baseline", CM) == \
        method_cold_latency_s(CM, "baseline")
    # prebaking's snapshot-evicted fallback is priced as a baseline start, so
    # a model with cheaper baselines floors there
    weird = CostModel(cold_warmswap_s=0.9, cold_prebaking_s=2.0,
                      cold_baseline_s=0.3, warm_s=0.004)
    assert min_cold_latency_s("prebaking", weird) == \
        method_cold_latency_s(weird, "baseline")
    # a (fuzzed) negative revive would make the pool-miss path the cheapest
    neg = CostModel(cold_warmswap_s=0.9, cold_prebaking_s=0.9,
                    cold_baseline_s=2.2, warm_s=0.004, image_revive_s=-0.1)
    assert min_cold_latency_s("warmswap", neg) == \
        method_cold_latency_s(neg, "warmswap") - 0.1
    with pytest.raises(KeyError):
        min_cold_latency_s("nope", CM)


def test_idle_bytes_units():
    assert idle_bytes_for("warmswap", CM) == CM.metadata_bytes
    assert idle_bytes_for("prebaking", CM) == CM.snapshot_bytes
    assert idle_bytes_for("baseline", CM) == CM.image_bytes
    with pytest.raises(ValueError):
        idle_bytes_for("nope", CM)


def test_empty_traces_floor():
    o = hindsight_floor([], "warmswap", CM)
    assert (o.n_invocations, o.n_cold, o.n_warm) == (0, 0, 0)
    assert o.total_latency_s == 0.0 and o.avg_latency_s == 0.0
    assert o.percentile(99) == 0.0


def test_gap_report_rejects_mismatched_traces():
    traces = generate_fleet_traces(n_functions=3, horizon_min=60.0, seed=0)
    o = hindsight_floor(traces, "warmswap", CM)
    r = _simulate_fleet_impl(traces[:1], "warmswap", CM, FleetConfig())
    with pytest.raises(ValueError, match="share traces"):
        gap_report(o, r)


def test_oracle_to_dict_drops_samples():
    traces = generate_fleet_traces(n_functions=3, horizon_min=60.0, seed=1)
    d = hindsight_floor(traces, "warmswap", CM).to_dict()
    assert "latency_samples_s" not in d
    assert set(d["latency_percentiles_s"]) == {"p50", "p90", "p95", "p99"}
    assert d["n_cold"] + d["n_warm"] == d["n_invocations"]


def test_oracle_from_scenario_matches_run():
    """The spec-level entry point resolves the same components run() does:
    its floor dominates (and shares a request count with) the spec's own
    engine results, under smoke overrides."""
    path = os.path.join(SCENARIOS_DIR, "tournament.json")
    scn = Scenario.from_file(path)
    res = run(scn, smoke=True)
    oracle = oracle_from_scenario(scn, smoke=True, traces=res.traces)
    assert set(oracle) == set(res.raw)
    for m, r in res.raw.items():
        assert_dominated(oracle[m], r, label=f"tournament/{m}")


# ---------------------------------------------------------------------------------
# Keep-alive frontier (report-only)
# ---------------------------------------------------------------------------------

def test_keepalive_frontier_shape():
    traces = generate_fleet_traces(n_functions=5, horizon_min=240.0, seed=3)
    for method in ("warmswap", "prebaking", "baseline"):
        pts = keepalive_frontier(traces, method, CM, n_points=7)
        mc = min_cold_latency_s(method, CM)
        n_req = sum(len(t.arrivals_min) for t in traces)
        n_fns = sum(1 for t in traces if len(t.arrivals_min))
        bms = [p.byte_minutes for p in pts]
        lats = [p.total_latency_s for p in pts]
        assert bms == sorted(bms)
        assert lats == sorted(lats, reverse=True)
        # endpoints: all-cold at zero byte-minutes; full coverage leaves one
        # cold per function
        assert pts[0].covered_gaps == 0 and pts[0].byte_minutes == 0.0
        assert pts[0].total_latency_s == n_req * mc
        assert pts[-1].covered_gaps == n_req - n_fns
        assert pts[-1].total_latency_s == pytest.approx(
            n_fns * mc + (n_req - n_fns) * CM.warm_s)
        # the frontier never dips below the sound floor
        floor = hindsight_floor(traces, method, CM)
        assert all(p.total_latency_s >= floor.total_latency_s - 1e-9
                   for p in pts)
