"""FunctionBench-analogue workload suite (paper Table 1 / §4.1).

Seven serverless function classes with the same *cost structure* as the paper's:

  | paper fn          | dependency image            | our image (base model)        |
  |-------------------|-----------------------------|-------------------------------|
  | helloworld        | bare Python (8.1 MB)        | py-base  (~8 MB blob)         |
  | json_dumps_load   | urllib/json (16 MB)         | py-base                       |
  | pyaes             | pyaes (8.3 MB)              | py-base                       |
  | chameleon         | chameleon (8.9 MB)          | py-base                       |
  | lr_serving        | sklearn+pandas (79 MB)      | model-tiny  (~2 MB params)    |
  | cnn_serving       | numpy+keras (190 MB)        | model-small (~16 MB params)   |
  | rnn_serving       | numpy+torch (200 MB)        | model-medium (~70 MB params)  |

Lightweight functions attach to the small shared runtime image (and therefore show the
paper's Fig. 5a behaviour: WarmSwap's migration overhead isn't amortized); serving
functions attach to progressively larger model images where dependency bring-up
(deserialize + XLA compile) dominates the cold start, as in the paper's Fig. 3.

Handlers are *real* computations (json round-trips, XOR block cipher rounds, HTML
table rendering, model prefill + classification head), so the execution phase is
measured, not simulated.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.registry import Registry
from repro.models.config import ArchConfig, GLOBAL_ATTN
from repro.models.transformer import forward, init_params

SERVE_BATCH = 1
SERVE_SEQ = 64


def _model_cfg(name: str, d: int, layers: int, vocab: int, ff_mult: int = 4) -> ArchConfig:
    return ArchConfig(
        name=name, family="dense", n_layers=layers, d_model=d,
        n_heads=max(d // 64, 1), n_kv_heads=max(d // 128, 1), d_ff=ff_mult * d,
        vocab_size=vocab, head_dim=64, attn_pattern=(GLOBAL_ATTN,),
        mlp="swiglu", tie_embeddings=True, max_seq_len=4096)


# The three model images (image id -> arch config)
IMAGE_CONFIGS: Dict[str, ArchConfig] = {
    "model-tiny": _model_cfg("model-tiny", 128, 2, 1024),
    "model-small": _model_cfg("model-small", 256, 4, 4096),
    "model-medium": _model_cfg("model-medium", 512, 8, 8192),
}
PY_BASE_BYTES = 8 << 20   # bare-runtime image blob size (paper: 8.1 MB)


def py_base_builder() -> Dict[str, np.ndarray]:
    """The 'bare Python runtime' image: an opaque pre-initialized blob."""
    rng = np.random.default_rng(0)
    return {"runtime_blob": rng.integers(0, 255, PY_BASE_BYTES, dtype=np.uint8)}


def model_params_builder(image_id: str, seed: int = 0) -> Callable[[], Any]:
    cfg = IMAGE_CONFIGS[image_id]
    def build():
        return init_params(jax.random.PRNGKey(seed), cfg, jnp.bfloat16)
    return build


def make_model_executables(image_id: str) -> Dict[str, Any]:
    """The image's pre-built executables (XLA-compile analogue of pre-imported
    middleware). Fresh wrappers of the same fns = the Baseline's per-cold-start
    compile."""
    cfg = IMAGE_CONFIGS[image_id]

    @jax.jit
    def prefill_logits(params, tokens):
        logits, _, _ = forward(params, tokens, cfg, logits_slice=1)
        return logits[:, -1]

    return {"prefill_logits": prefill_logits}


def warm_executables(execs: Dict[str, Any], params: Any, image_id: str) -> None:
    """Trigger compilation (used once at image build; the Baseline pays this per
    cold start)."""
    cfg = IMAGE_CONFIGS[image_id]
    tokens = jnp.zeros((SERVE_BATCH, SERVE_SEQ), jnp.int32)
    jax.block_until_ready(execs["prefill_logits"](params, tokens))


# ---------------------------------------------------------------------------------
# Handlers (the user code; never part of the shared image)
# ---------------------------------------------------------------------------------

def _head_builder(image_id: Optional[str], n_classes: int = 16, seed: int = 1):
    def build() -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(seed)
        if image_id is None or image_id == "py-base":
            return {"bias": rng.normal(size=(n_classes,)).astype(np.float32)}
        d = IMAGE_CONFIGS[image_id].d_model
        vp = ((IMAGE_CONFIGS[image_id].vocab_size + 511) // 512) * 512
        return {"w": (rng.normal(size=(vp, n_classes)) / np.sqrt(d)).astype(np.float32),
                "bias": np.zeros((n_classes,), np.float32)}
    return build


def handler_helloworld(params, hw, request, execs):
    return "hello world"


def handler_json(params, hw, request, execs):
    doc = {"items": [{"i": i, "v": float(i) * 1.5, "s": "x" * 32} for i in range(2000)]}
    for _ in range(5):
        doc = json.loads(json.dumps(doc))
    return len(json.dumps(doc))


def handler_pyaes(params, hw, request, execs):
    rng = np.random.default_rng(42)
    data = rng.integers(0, 255, 100_000, dtype=np.uint8)
    key = rng.integers(0, 255, 16, dtype=np.uint8)
    for r in range(10):                       # XOR block-cipher rounds (pyaes analogue)
        data = np.bitwise_xor(data, np.roll(np.resize(key, data.shape), r))
        data = np.roll(data, 7)
    return int(data.sum())


def handler_chameleon(params, hw, request, execs):
    rows = ["<tr>" + "".join(f"<td>{i}-{j}</td>" for j in range(10)) + "</tr>"
            for i in range(1500)]
    table = "<table>" + "".join(rows) + "</table>"
    return len(table)


def _handler_serving(params, hw, request, execs):
    tokens = jnp.asarray(request["tokens"], jnp.int32)
    logits = execs["prefill_logits"](params, tokens)          # (B, Vp)
    cls = jnp.argmax(logits @ jnp.asarray(hw["w"]) + hw["bias"], axis=-1)
    return np.asarray(cls)


@dataclass
class Workload:
    fn_id: str
    image_id: str
    handler_fn: Callable
    handler_builder: Callable
    request_builder: Callable[[], Any]
    # leaves the handler actually touches (LAZY restore transfers only these;
    # None = the whole image, the common case)
    touch_keys: Optional[List[str]] = None


def default_request():
    rng = np.random.default_rng(7)
    return {"tokens": rng.integers(0, 1000, (SERVE_BATCH, SERVE_SEQ), dtype=np.int32)}


#: Name -> :class:`Workload` registry (dict-shaped reads keep working: ``in``,
#: ``[...]``, ``list(...)``, ``.get``). New workload classes plug in with
#: ``WORKLOADS.register("name", Workload(...))`` — nothing in the bench/
#: orchestrator stack enumerates a hard-coded list.
WORKLOADS: Registry = Registry("workload")
for _w in (
    Workload("helloworld", "py-base", handler_helloworld,
             _head_builder(None), lambda: {}),
    Workload("json_dumps_load", "py-base", handler_json,
             _head_builder(None), lambda: {}),
    Workload("pyaes", "py-base", handler_pyaes,
             _head_builder(None), lambda: {}),
    Workload("chameleon", "py-base", handler_chameleon,
             _head_builder(None), lambda: {}),
    Workload("lr_serving", "model-tiny", _handler_serving,
             _head_builder("model-tiny"), default_request),
    Workload("cnn_serving", "model-small", _handler_serving,
             _head_builder("model-small"), default_request),
    Workload("rnn_serving", "model-medium", _handler_serving,
             _head_builder("model-medium"), default_request),
):
    WORKLOADS.register(_w.fn_id, _w)
del _w
