"""Registries: component name -> factory, plus the serverless function registry.

Two distinct things live here:

  * :class:`Registry` — the general name -> component pattern every pluggable
    axis of the simulators uses (pre-warm policies, placement strategies, cost
    models, trace generators, workloads). String keys are what makes the
    declarative :mod:`~repro.core.scenario` spec serializable: a scenario
    names its components, the registries build them. Unknown keys fail with
    did-you-mean suggestions.
  * :class:`FunctionRegistry` — serverless endpoints = shared image ref +
    per-tenant handler. The paper's isolation argument (§1) holds by
    construction here: the dependency image contains only the *public* base
    model; user-specific state (the handler head weights and the handler
    callable) never enters the shared pool. What Prebaking would snapshot per
    function — base + handler together — the registry keeps factored.
"""
from __future__ import annotations

import difflib
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

import numpy as np


def did_you_mean(name: str, choices) -> str:
    """A ``" — did you mean ...?"`` suffix for an unknown-key error message,
    or ``""`` when nothing is close. Shared by :class:`Registry` and the
    scenario spec validators."""
    close = difflib.get_close_matches(str(name), list(choices), n=3)
    return f" — did you mean {', '.join(map(repr, close))}?" if close else ""


class UnknownComponentError(ValueError, KeyError):
    """A registry lookup failed; the message carries did-you-mean hints.

    Subclasses both :class:`ValueError` (what the simulators historically
    raised for unknown names) and :class:`KeyError` (what a dict-shaped
    lookup raises), so pre-registry ``except`` clauses keep working.
    """

    # KeyError.__str__ repr-quotes the message; keep plain Exception rendering
    __str__ = Exception.__str__


class Registry:
    """Name -> component registry with a ``@register("name")`` decorator.

    Components plug into the engines by string key — the unit of
    serializability for scenario specs — without the engine ever naming the
    concrete class. Registered objects are usually factories (classes or
    functions); :meth:`build` calls them with per-component kwargs. A
    registry can also hold plain instances (e.g. the workload suite), in
    which case :meth:`build` returns them as-is when no kwargs are given.

    Dict-shaped reads (``in``, ``[...]``, iteration over names, ``get``)
    are supported so pre-registry call sites keep working unchanged.
    """

    def __init__(self, kind: str):
        self.kind = kind                      # human label for error messages
        self._entries: Dict[str, Any] = {}

    # ------------------------------------------------------------ registration
    def register(self, name: str, obj: Any = None):
        """Register ``obj`` under ``name``; usable as a decorator.

        ``@REG.register("x")`` on a class/function registers it and returns
        it unchanged; ``REG.register("x", obj)`` registers directly.
        Re-registering a taken name raises (shadowing a component silently
        would make scenario specs ambiguous).
        """
        if obj is None:
            def deco(target):
                self.register(name, target)
                return target
            return deco
        if name in self._entries:
            raise ValueError(f"{self.kind} {name!r} is already registered")
        self._entries[name] = obj
        return obj

    # ----------------------------------------------------------------- lookup
    def resolve(self, name: str) -> Any:
        """The registered object for ``name``; unknown names raise
        :class:`UnknownComponentError` with did-you-mean suggestions."""
        try:
            return self._entries[name]
        except KeyError:
            raise UnknownComponentError(
                f"unknown {self.kind}: {name!r} "
                f"(choose from {sorted(self._entries)})"
                f"{did_you_mean(name, self._entries)}") from None

    def build(self, name: str, **kwargs) -> Any:
        """Instantiate the component: call the registered factory with
        ``kwargs``. A non-callable entry (a plain registered instance) is
        returned as-is when no kwargs are given."""
        obj = self.resolve(name)
        if not callable(obj):
            if kwargs:
                raise TypeError(f"{self.kind} {name!r} is a plain instance "
                                f"and takes no kwargs, got {sorted(kwargs)}")
            return obj
        return obj(**kwargs)

    def names(self) -> List[str]:
        """Registered names in registration order (dict-read semantics —
        callers that enumerate components see the curated order; error
        messages sort independently)."""
        return list(self._entries)

    # ------------------------------------------------------- dict-shaped reads
    def get(self, name: str, default: Any = None) -> Any:
        return self._entries.get(name, default)

    def __contains__(self, name: object) -> bool:
        return name in self._entries

    def __getitem__(self, name: str) -> Any:
        return self.resolve(name)

    def __iter__(self) -> Iterator[str]:
        return iter(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, {self.names()})"


@dataclass
class FunctionSpec:
    fn_id: str
    image_id: str                     # shared dependency image this endpoint needs
    handler_builder: Callable[[], Dict[str, np.ndarray]]  # per-tenant weights (small)
    handler_fn: Callable[..., Any]    # handler(params, handler_weights, request)
    # provider-side artifacts
    checkpoint_path: Optional[str] = None   # baseline path: full per-fn checkpoint
    handler_bytes: int = 0
    # Provenance timestamp on the live registry entry; simulated results
    # never read it.  # repro-lint: allow[wall-clock]
    registered_at: float = field(default_factory=time.time)


class FunctionRegistry:
    def __init__(self, store_dir: Optional[str] = None):
        self.store_dir = store_dir
        self._fns: Dict[str, FunctionSpec] = {}

    def register(
        self,
        fn_id: str,
        image_id: str,
        handler_builder: Callable[[], Dict[str, np.ndarray]],
        handler_fn: Callable[..., Any],
        *,
        base_params_builder: Optional[Callable[[], Any]] = None,
        write_baseline_checkpoint: bool = False,
    ) -> FunctionSpec:
        """Registering a function is the paper's *setup phase* (Fig. 4b): the user
        uploads code + handler; the provider may also write the traditional full
        per-function container checkpoint (what the Baseline cold start loads)."""
        hw = handler_builder()
        hbytes = sum(np.asarray(v).nbytes for v in hw.values())
        ckpt = None
        if write_baseline_checkpoint and self.store_dir and base_params_builder:
            import jax
            os.makedirs(self.store_dir, exist_ok=True)
            ckpt = os.path.join(self.store_dir, f"{fn_id}.npz")
            params = base_params_builder()
            flat = {}
            for i, l in enumerate(jax.tree_util.tree_leaves(params)):
                arr = np.asarray(l)
                if arr.dtype.name == "bfloat16":  # npz can't hold bf16: view as u16
                    flat[f"p{i}:bf16"] = arr.view(np.uint16)
                else:
                    flat[f"p{i}"] = arr
            flat.update({f"h_{k}": np.asarray(v) for k, v in hw.items()})
            np.savez(ckpt, **flat)
        spec = FunctionSpec(fn_id=fn_id, image_id=image_id,
                            handler_builder=handler_builder, handler_fn=handler_fn,
                            checkpoint_path=ckpt, handler_bytes=hbytes)
        self._fns[fn_id] = spec
        return spec

    def get(self, fn_id: str) -> FunctionSpec:
        return self._fns[fn_id]

    def list(self) -> List[str]:
        return sorted(self._fns)

    def functions_sharing(self, image_id: str) -> List[str]:
        return [f for f, s in self._fns.items() if s.image_id == image_id]
