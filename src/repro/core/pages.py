"""Parameter paging: pytree <-> fixed-size pages in a host-RAM store.

This is WarmSwap's memory-page layer adapted to model weights (DESIGN.md §2): a
dependency image's "hot memory pages" become fixed-size byte pages of the pre-sharded
parameter pytree, laid out in **layer order** so bulk restore streams pages in the
order the forward pass consumes them (the paper orders checkpoint images on disk for
the same reason, §3.2).

The page table (leaf path -> page span) is part of the image *metadata*: small,
structure-only, and exactly what the migration client needs to restore the pytree —
mirroring CRIU's split between process metadata and memory pages (Table 3).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import numpy as np

DEFAULT_PAGE_SIZE = 1 << 22  # 4 MiB


@dataclass
class LeafEntry:
    key: str                 # keystr path of the leaf
    shape: Tuple[int, ...]
    dtype: str               # numpy dtype name ('bfloat16' handled via jnp)
    nbytes: int
    first_page: int
    n_pages: int
    offset: int              # byte offset of this leaf inside its first page == 0 here
    layer_index: int         # streaming order group

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclass
class PageTable:
    page_size: int
    entries: Dict[str, LeafEntry]
    n_pages: int
    order: List[str] = field(default_factory=list)       # leaf keys in streaming order
    tree_order: List[str] = field(default_factory=list)  # leaf keys in tree-flatten order

    @property
    def nbytes_pages(self) -> int:
        return self.n_pages * self.page_size

    @property
    def nbytes_payload(self) -> int:
        return sum(e.nbytes for e in self.entries.values())

    def metadata_bytes(self) -> int:
        """Size of the serialized table — the paper's 'process metadata' size."""
        return len(self.to_json().encode())

    def to_json(self) -> str:
        return json.dumps({
            "page_size": self.page_size,
            "n_pages": self.n_pages,
            "order": self.order,
            "tree_order": self.tree_order,
            "entries": {k: e.to_json() for k, e in self.entries.items()},
        })

    @classmethod
    def from_json(cls, s: str) -> "PageTable":
        d = json.loads(s)
        entries = {k: LeafEntry(**{**v, "shape": tuple(v["shape"])})
                   for k, v in d["entries"].items()}
        return cls(page_size=d["page_size"], entries=entries,
                   n_pages=d["n_pages"], order=list(d["order"]),
                   tree_order=list(d.get("tree_order", [])))


def _np_view(x) -> np.ndarray:
    """Numpy byte view of an array (bf16 -> uint16 reinterpretation)."""
    arr = np.asarray(x)
    return arr.view(np.uint8).reshape(-1) if arr.dtype != object else arr


def _streaming_order(keys: Sequence[str]) -> List[str]:
    """Embed first (needed at step start), then scanned units, remainder, the rest."""
    def rank(k: str) -> Tuple[int, str]:
        if "embed" in k and "tok" in k:
            return (0, k)
        if k.startswith("['unit']") or "['unit']" in k:
            return (1, k)
        if "['rem']" in k:
            return (2, k)
        if "enc" in k:
            return (3, k)
        if "final_norm" in k:
            return (4, k)
        return (5, k)
    return sorted(keys, key=rank)


def paginate(params: Any, page_size: int = DEFAULT_PAGE_SIZE
             ) -> Tuple[np.ndarray, PageTable, Any]:
    """Flatten ``params`` into (page_store (n_pages, page_size) uint8, table, treedef).

    Every leaf starts on a page boundary (pages are the transfer/sharing unit;
    sub-page packing would couple unrelated leaves into one fault).
    """
    leaves_with_paths = jax.tree_util.tree_leaves_with_path(params)
    treedef = jax.tree_util.tree_structure(params)
    by_key = {}
    tree_order = []
    for path, leaf in leaves_with_paths:
        key = jax.tree_util.keystr(path)
        by_key[key] = leaf
        tree_order.append(key)
    order = _streaming_order(list(by_key.keys()))

    entries: Dict[str, LeafEntry] = {}
    chunks: List[np.ndarray] = []
    page_cursor = 0
    for li, key in enumerate(order):
        leaf = by_key[key]
        arr = np.asarray(leaf)
        raw = arr.tobytes()                      # C-order: stacked leaves are unit-major
        n_pages = max(1, -(-len(raw) // page_size))
        buf = np.zeros(n_pages * page_size, np.uint8)
        buf[: len(raw)] = np.frombuffer(raw, np.uint8)
        chunks.append(buf.reshape(n_pages, page_size))
        entries[key] = LeafEntry(
            key=key, shape=tuple(arr.shape), dtype=str(arr.dtype),
            nbytes=len(raw), first_page=page_cursor, n_pages=n_pages,
            offset=0, layer_index=li)
        page_cursor += n_pages
    store = (np.concatenate(chunks, axis=0) if chunks
             else np.zeros((0, page_size), np.uint8))
    table = PageTable(page_size=page_size, entries=entries,
                      n_pages=page_cursor, order=order, tree_order=tree_order)
    return store, table, treedef


def materialize_leaf(store: np.ndarray, table: PageTable, key: str) -> np.ndarray:
    e = table.entries[key]
    raw = store[e.first_page: e.first_page + e.n_pages].reshape(-1)[: e.nbytes]
    dt = np.dtype(e.dtype) if e.dtype != "bfloat16" else None
    if dt is None:
        import ml_dtypes
        dt = np.dtype(ml_dtypes.bfloat16)
    return np.frombuffer(raw.tobytes(), dtype=dt).reshape(e.shape)


def materialize(store: np.ndarray, table: PageTable, treedef,
                keys: Optional[Iterable[str]] = None) -> Any:
    """Rebuild the full pytree (or, with ``keys``, a {key: array} subset)."""
    if keys is not None:
        return {k: materialize_leaf(store, table, k) for k in keys}
    leaves = [materialize_leaf(store, table, k) for k in table.tree_order]
    return jax.tree_util.tree_unflatten(treedef, leaves)
