"""Fault tolerance: supervised training, failure injection, pool-based recovery.

Training side — :class:`TrainSupervisor`:
  * periodic async checkpoints (atomic; resharding-capable);
  * automatic rollback-and-resume on NaN/Inf loss or injected step failures, with
    deterministic data replay (the pipeline is a pure function of (seed, step));
  * elastic restart: resume the same checkpoint at a different DP width.

Serving side — :class:`ReplicaSet`:
  * N replicas fronted by the straggler-aware FleetScheduler;
  * ``kill()`` simulates node failure; ``recover()`` re-warms the replacement from
    the WarmSwap dependency pool — the measured claim that pool-based re-warm beats
    cold-loading the model store is the paper's cold-start result wearing its
    fault-tolerance hat.
"""
from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointConfig, Checkpointer, latest_step


@dataclass
class SupervisorConfig:
    checkpoint_every: int = 20
    max_retries: int = 3
    checkpoint: Optional[CheckpointConfig] = None


class InjectedFailure(RuntimeError):
    pass


class TrainSupervisor:
    """Wraps a step function with checkpoint/rollback/NaN-recovery semantics."""

    def __init__(
        self,
        cfg: SupervisorConfig,
        train_step: Callable,                       # (params, opt, batch, step)->(p,o,m)
        batch_at: Callable[[int], Dict[str, Any]], # deterministic data access
    ):
        self.cfg = cfg
        self.train_step = train_step
        self.batch_at = batch_at
        self.ckpt = Checkpointer(cfg.checkpoint) if cfg.checkpoint else None
        self.restores = 0
        self.failures_seen = 0

    def _bad(self, metrics: Dict[str, Any]) -> bool:
        loss = float(metrics.get("loss", 0.0))
        return math.isnan(loss) or math.isinf(loss)

    def run(
        self,
        params: Any,
        opt_state: Any,
        start_step: int,
        n_steps: int,
        *,
        fail_at: Optional[Dict[int, BaseException]] = None,   # injected failures
        on_metrics: Optional[Callable[[int, Dict], None]] = None,
    ):
        """Runs [start_step, start_step+n_steps) with recovery. Returns
        (params, opt_state, history)."""
        fail_at = dict(fail_at or {})
        history: List[Dict[str, Any]] = []
        step = start_step
        end = start_step + n_steps
        retries = 0
        if self.ckpt is not None and latest_step(self.cfg.checkpoint.directory) is None:
            # anchor checkpoint: a failure before the first periodic save can still
            # roll back to the run's starting state
            self.ckpt.save(start_step, {"params": params, "opt_state": opt_state})
            self.ckpt.wait()
        while step < end:
            try:
                if step in fail_at:
                    exc = fail_at.pop(step)
                    self.failures_seen += 1
                    raise exc
                batch = self.batch_at(step)
                new_p, new_o, metrics = self.train_step(
                    params, opt_state, batch, jnp.asarray(step, jnp.int32))
                if self._bad(jax.device_get(metrics)):
                    raise InjectedFailure(f"non-finite loss at step {step}")
                params, opt_state = new_p, new_o
                m = {k: float(v) for k, v in jax.device_get(metrics).items()}
                m["step"] = step
                history.append(m)
                if on_metrics:
                    on_metrics(step, m)
                if self.ckpt and (step + 1) % self.cfg.checkpoint_every == 0:
                    self.ckpt.save(step + 1, {"params": params,
                                              "opt_state": opt_state})
                step += 1
                retries = 0
            except (InjectedFailure, FloatingPointError, RuntimeError) as e:
                retries += 1
                if retries > self.cfg.max_retries or self.ckpt is None:
                    raise
                restored = self.ckpt.restore(None, {"params": params,
                                                    "opt_state": opt_state})
                if restored is None:
                    raise RuntimeError("failure before first checkpoint") from e
                params = restored["params"]
                opt_state = restored["opt_state"]
                step = int(restored["__manifest__"]["step"])
                self.restores += 1
        if self.ckpt:
            self.ckpt.save(step, {"params": params, "opt_state": opt_state})
            self.ckpt.wait()
        return params, opt_state, history


# ---------------------------------------------------------------------------------
# Serving-side failure/recovery
# ---------------------------------------------------------------------------------

@dataclass
class RecoveryEvent:
    replica: str
    method: str
    seconds: float


class ReplicaSet:
    """A set of serving replicas with pool-backed replacement."""

    def __init__(self, manager, image_id: str, cfg, make_engine: Callable,
                 n_replicas: int = 2):
        from repro.serving.scheduler import FleetScheduler
        self.manager = manager
        self.image_id = image_id
        self.cfg = cfg
        self.make_engine = make_engine
        self.scheduler = FleetScheduler()
        # kill()/recover() may race with a supervisor thread driving _spawn;
        # membership and the recovery log are lock-guarded (repro-lint
        # verifies the discipline statically — see docs/ANALYSIS.md).
        self._lock = threading.Lock()
        self.replicas: Dict[str, Any] = {}       # guarded-by: _lock
        self.events: List[RecoveryEvent] = []    # guarded-by: _lock
        for i in range(n_replicas):
            self._spawn(f"replica-{i}", method="warmswap")

    def _spawn(self, name: str, method: str) -> float:
        # Engine bring-up (build/restore + compile) happens outside the lock:
        # it is the slow path being measured and touches no shared state.
        t0 = time.perf_counter()
        engine = self.make_engine(self.manager, self.image_id,
                                  self.cfg, method)
        dt = time.perf_counter() - t0
        with self._lock:
            self.replicas[name] = engine
            self.scheduler.register_replica(name)
            self.events.append(RecoveryEvent(name, method, dt))
        return dt

    def kill(self, name: str) -> None:
        """Simulated node failure."""
        with self._lock:
            self.replicas.pop(name, None)
            self.scheduler.remove_replica(name)

    def recover(self, name: str, method: str = "warmswap") -> float:
        """Replace a failed replica; returns bring-up seconds. 'warmswap' re-warms
        from the dependency pool; 'baseline' cold-loads + recompiles."""
        return self._spawn(name, method=method)


def replay_disruption(replicas: ReplicaSet, schedule,
                      method: str = "warmswap") -> List[RecoveryEvent]:
    """Replay a simulator disruption schedule against a live :class:`ReplicaSet`.

    This is the bridge between the fleet simulator's foul-weather axes
    (``core/disruption.py``) and the runtime recovery story measured here:
    the same :class:`~repro.core.disruption.DisruptionSchedule` a
    ``FleetConfig`` replays as timed events is applied to real replicas —
    worker ``i`` maps to ``"replica-{i}"`` — so the simulated churn scenario
    and the live pool-backed recovery claim share one schedule artifact.

    Events are applied in schedule order (already time-sorted), collapsed to
    their effects: ``worker_fail`` kills the replica, ``worker_recover``
    re-warms it via ``recover(..., method)``, and ``cache_flush`` is a
    no-op here (the live pool has no fleet-wide eviction hook; the
    simulator prices that axis). Wall-clock timing is *not* reproduced —
    only the event sequence is.

    Returns the :class:`RecoveryEvent` list for the recoveries this replay
    itself triggered (bring-up seconds per re-warm), in order.
    """
    before = len(replicas.events)
    for ev in schedule.events:
        name = f"replica-{ev.worker}"
        if ev.kind == "worker_fail":
            replicas.kill(name)
        elif ev.kind == "worker_recover":
            replicas.recover(name, method=method)
        # cache_flush: no live-pool analogue; simulator-only axis
    with replicas._lock:
        return list(replicas.events[before:])
