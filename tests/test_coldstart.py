"""Cold-start orchestration: WarmSwap vs Baseline vs Prebaking behaviour
(paper Figs. 5/6, Table 2 semantics)."""
import tempfile

import numpy as np
import pytest

from repro.core import (
    ColdStartConfig,
    ColdStartOrchestrator,
    DependencyManager,
    FunctionRegistry,
    RestorePolicy,
)
from repro.core import workloads as wl


@pytest.fixture(scope="module")
def stack():
    tmp = tempfile.mkdtemp()
    mgr = DependencyManager(disk_dir=tmp + "/pool")
    reg = FunctionRegistry(store_dir=tmp + "/store")
    mgr.register_image("py-base", "py-base", wl.py_base_builder)
    builder = wl.model_params_builder("model-tiny")
    execs = wl.make_model_executables("model-tiny")
    wl.warm_executables(execs, builder(), "model-tiny")
    mgr.register_image("model-tiny", "model-tiny", builder, executables=execs)
    for fn in ["helloworld", "pyaes", "lr_serving"]:
        w = wl.WORKLOADS[fn]
        bb = (wl.model_params_builder(w.image_id)
              if w.image_id in wl.IMAGE_CONFIGS else wl.py_base_builder)
        reg.register(fn, w.image_id, w.handler_builder, w.handler_fn,
                     base_params_builder=bb, write_baseline_checkpoint=True)
    orch = ColdStartOrchestrator(mgr, reg, ColdStartConfig())
    return mgr, reg, orch


def test_warmswap_and_baseline_agree_on_results(stack):
    """Isolation + correctness: the migrated instance computes the same answers."""
    _, reg, orch = stack
    inst_b, _ = orch.cold_start_baseline("lr_serving")
    inst_w, _ = orch.cold_start_warmswap("lr_serving")
    req = wl.WORKLOADS["lr_serving"].request_builder()
    rb, _ = inst_b.invoke(req)
    rw, _ = inst_w.invoke(req)
    assert np.array_equal(np.asarray(rb), np.asarray(rw))


def test_phase_breakdown_structure(stack):
    _, _, orch = stack
    _, tb = orch.cold_start_baseline("lr_serving")
    _, tw = orch.cold_start_warmswap("lr_serving")
    # baseline pays dependency_init; warmswap pays communication+migration instead
    assert tb.dependency_init > 0 and tb.communication == 0
    assert tw.dependency_init == 0 and tw.migration > 0
    assert tw.total < tb.total  # model-image function: WarmSwap wins (Fig. 5a)


def test_warm_start_unaffected(stack):
    """Paper Fig. 5b: warm-start latency identical across start methods."""
    _, _, orch = stack
    inst_b, _ = orch.cold_start_baseline("lr_serving")
    inst_w, _ = orch.cold_start_warmswap("lr_serving")
    req = wl.WORKLOADS["lr_serving"].request_builder()
    lat_b = min(inst_b.invoke(req)[1] for _ in range(3))
    lat_w = min(inst_w.invoke(req)[1] for _ in range(3))
    assert lat_w < 5 * lat_b + 0.05  # same order (noise-tolerant bound)


def test_prebaking_memory_scales_with_functions(stack):
    """WarmSwap pool = O(images); Prebaking = O(functions) (Fig. 7 memory)."""
    mgr, reg, orch = stack
    orch.prebake("helloworld")
    one = orch.prebaked_bytes()
    orch.prebake("pyaes")  # same image, different function
    two = orch.prebaked_bytes()
    assert two >= 2 * one * 0.9            # prebaking duplicates the base image
    pool_before = mgr.pool_bytes()
    orch.cold_start_warmswap("helloworld")
    orch.cold_start_warmswap("pyaes")
    assert mgr.pool_bytes() == pool_before  # pool unchanged: image shared


def test_prebaked_cold_start_works(stack):
    _, _, orch = stack
    orch.prebake("lr_serving")
    inst, t = orch.cold_start_prebaked("lr_serving")
    req = wl.WORKLOADS["lr_serving"].request_builder()
    r, _ = inst.invoke(req)
    assert r is not None and t.migration > 0


@pytest.mark.parametrize("policy", [RestorePolicy.BULK, RestorePolicy.LAZY,
                                    RestorePolicy.NO_PAGESERVER,
                                    RestorePolicy.NO_LAZY])
def test_all_policies_cold_start(stack, policy):
    """Table 2: every prototype variant produces a working instance."""
    _, _, orch = stack
    inst, t = orch.cold_start_warmswap("lr_serving", policy=policy)
    req = wl.WORKLOADS["lr_serving"].request_builder()
    r, _ = inst.invoke(req)
    assert r is not None
    assert t.total > 0
