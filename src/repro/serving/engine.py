"""Continuous-batching serving engine with WarmSwap-backed replica bring-up.

The engine owns a fixed pool of decode slots over one batched decode state:

  * ``submit()`` queues requests; admission prefills each (B=1, its own length) and
    splices the resulting KV/recurrent state into a free slot — in-flight requests
    never stall behind a new prefill longer than one engine step;
  * ``step()`` runs one batched ``serve_step`` for ALL slots (parked slots decode
    garbage into their own ring slot — harmless, reset on admission) and retires
    finished requests (EOS or token budget);
  * per-slot position streams come from the per-batch ``k_pos``/``pos`` machinery in
    the model, so slots at different depths coexist in one jitted step.

Replica bring-up is WarmSwap's job: ``ServingEngine.from_pool`` live-migrates the
base-model image out of the DependencyManager (compile-cache + page stream) instead
of cold-loading from a store — this is also the node-failure recovery path
(runtime/fault_tolerance.py measures it).
"""
from __future__ import annotations

import collections
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import make_serve_step_with_logits
from repro.models.config import ArchConfig
from repro.models.transformer import forward, init_decode_state
from repro.serving.state_utils import state_reset_slot, state_splice


@dataclass
class ServeConfig:
    max_slots: int = 4
    max_seq_len: int = 512
    max_new_tokens: int = 32
    eos_id: int = -1                # -1: disabled (synthetic vocab has no EOS)
    temperature: float = 0.0        # 0 = greedy
    seed: int = 0


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int
    submitted_at: float = field(default_factory=time.monotonic)
    prefilled_at: Optional[float] = None
    finished_at: Optional[float] = None
    tokens: List[int] = field(default_factory=list)

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.prefilled_at is None else self.prefilled_at - self.submitted_at

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.finished_at is None else self.finished_at - self.submitted_at


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params: Any,
                 serve_cfg: Optional[ServeConfig] = None):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg if serve_cfg is not None else ServeConfig()
        B = self.scfg.max_slots
        self.state = init_decode_state(cfg, B, self.scfg.max_seq_len, jnp.float32)
        self._serve_step = jax.jit(make_serve_step_with_logits(cfg))
        self._queue: Deque[Request] = collections.deque()
        self._slots: List[Optional[Request]] = [None] * B
        self._next_tok = np.zeros((B, 1), np.int32)
        self._rid = itertools.count()
        self.completed: Dict[int, Request] = {}
        self._rng = np.random.default_rng(self.scfg.seed)
        self.steps = 0

    # ------------------------------------------------------------------ intake
    def submit(self, prompt: np.ndarray, max_new_tokens: Optional[int] = None) -> int:
        req = Request(next(self._rid), np.asarray(prompt, np.int32),
                      max_new_tokens or self.scfg.max_new_tokens)
        self._queue.append(req)
        return req.rid

    # ------------------------------------------------------------------ admission
    def _admit(self) -> None:
        for slot in range(self.scfg.max_slots):
            if self._slots[slot] is not None or not self._queue:
                continue
            req = self._queue.popleft()
            tokens = jnp.asarray(req.prompt[None, :], jnp.int32)
            logits, _, single = forward(
                self.params, tokens, self.cfg, make_state=True,
                state_len=self.scfg.max_seq_len, logits_slice=1)
            first = self._sample(np.asarray(logits[:, -1, : self.cfg.vocab_size]))
            req.prefilled_at = time.monotonic()
            req.tokens.append(int(first[0]))
            self.state = state_reset_slot(self.state, slot)
            self.state = state_splice(self.state, single, slot)
            self._slots[slot] = req
            self._next_tok[slot, 0] = first[0]

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.scfg.temperature <= 0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits / self.scfg.temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self._rng.choice(len(row), p=row) for row in p], np.int32)

    # ------------------------------------------------------------------ one step
    def step(self) -> int:
        """Admit, decode one token for every active slot; returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self._slots) if r is not None]
        if not active:
            return 0
        logits, self.state = self._serve_step(
            self.params, self.state, jnp.asarray(self._next_tok))
        toks = self._sample(np.asarray(logits))
        self.steps += 1
        now = time.monotonic()
        for slot in active:
            req = self._slots[slot]
            req.tokens.append(int(toks[slot]))
            self._next_tok[slot, 0] = toks[slot]
            done = (len(req.tokens) >= req.max_new_tokens or
                    (self.scfg.eos_id >= 0 and toks[slot] == self.scfg.eos_id))
            if done:
                req.finished_at = now
                self.completed[req.rid] = req
                self._slots[slot] = None
        return len(active)

    def run_until_done(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self._queue and all(s is None for s in self._slots):
                return
            self.step()

    # ------------------------------------------------------------------ bring-up
    @classmethod
    def from_pool(cls, manager, image_id: str, cfg: ArchConfig,
                  serve_cfg: Optional[ServeConfig] = None, policy=None):
        """WarmSwap replica bring-up: live-migrate the base image from the pool."""
        from repro.core.migration import RestorePolicy
        restored = manager.request_migration(image_id, policy or RestorePolicy.BULK)
        params = restored.as_pytree()
        manager.release(image_id)
        return cls(cfg, params, serve_cfg)

    # ------------------------------------------------------------------ metrics
    def metrics(self) -> Dict[str, float]:
        done = list(self.completed.values())
        if not done:
            return {"completed": 0}
        return {
            "completed": len(done),
            "mean_ttft_s": float(np.mean([r.ttft_s for r in done])),
            "mean_latency_s": float(np.mean([r.latency_s for r in done])),
            "engine_steps": self.steps,
        }
