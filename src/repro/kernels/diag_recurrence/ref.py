"""Pure-jnp oracle for the chunked diagonal linear recurrence kernel.

Same contract as ``repro.models.recurrence.chunked_diag_recurrence`` restricted to
2-D channel layout: h_t = a_t * h_{t-1} + b_t, a/b: (B, S, C), h0: (B, C).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def diag_recurrence_ref(a: jax.Array, b: jax.Array, h0: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    a_t = jnp.moveaxis(a, 1, 0)
    b_t = jnp.moveaxis(b, 1, 0)
    h_final, h_all = jax.lax.scan(step, h0, (a_t, b_t))
    return jnp.moveaxis(h_all, 0, 1), h_final
