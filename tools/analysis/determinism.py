"""Determinism checker: the repo's bit-identity guarantee, enforced at the AST.

Every simulation result must be a pure function of (spec, seed) — that is
what lets serial vs parallel sweeps assert byte-identity and the vectorized
engine assert sha256-identity against the event engine. This checker flags
the ways nondeterminism historically sneaks in:

* ``unseeded-rng`` — module-level ``np.random.*`` / bare ``random.*`` calls
  (global RNG state), and ``default_rng()`` / ``Random()`` with no seed;
* ``wall-clock`` — ``time.time`` / ``datetime.now`` / ``time.monotonic``
  references in simulation code (monotonic *interval* timers such as
  ``perf_counter`` are sanctioned bench timers, config.SANCTIONED_TIMERS);
* ``hash-randomization`` — builtin ``hash()`` on simulation inputs: salted
  per process by PYTHONHASHSEED, so it is not stable across runs;
* ``set-iteration`` — iterating a set (or joining/listing one) where order
  flows into outputs; set order is hash-order, wrap in ``sorted(...)``;
* ``environ-read`` — ``os.environ`` / ``os.getenv`` outside the declared
  config entry points (config.SANCTIONED_ENVIRON).

Scope: ``config.DETERMINISM_SCOPE``. Sanction individual live-side sites
(the Dependency Manager's LRU clock, registration timestamps) with
``# repro-lint: allow[wall-clock]``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from tools.analysis import config
from tools.analysis.base import (SourceFile, dotted_name,
                                 enclosing_function_name, qualname_index)
from tools.analysis.findings import Finding

CHECKER = "determinism"

#: Constructors that are *seeded RNG factories* when called with arguments.
_SEEDED_FACTORIES = {"default_rng", "Generator", "SeedSequence", "PCG64",
                     "Philox", "MT19937", "SFC64", "RandomState", "Random"}
_WALL_CLOCK_ATTRS = {"time", "monotonic", "monotonic_ns"}
_DATETIME_NOW = {"now", "utcnow", "today"}


def _import_aliases(tree: ast.Module) -> Dict[str, Set[str]]:
    """Local alias sets for the modules the rules care about, plus names
    imported *from* them (``from time import time`` -> bare-name hits)."""
    mods: Dict[str, Set[str]] = {"numpy": set(), "random": set(), "time": set(),
                                 "datetime": set(), "os": set()}
    from_names: Dict[str, Set[str]] = {"random": set(), "time": set(),
                                       "datetime": set(), "os": set()}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                root = a.name.split(".")[0]
                if root in mods:
                    mods[root].add(a.asname or root)
        elif isinstance(node, ast.ImportFrom) and node.module:
            root = node.module.split(".")[0]
            if root in from_names:
                for a in node.names:
                    from_names[root].add(a.asname or a.name)
    return {"mods": mods, "from": from_names}  # type: ignore[return-value]


def check(src: SourceFile) -> List[Finding]:
    if not config.in_scope(src.rel, config.DETERMINISM_SCOPE):
        return []
    aliases = _import_aliases(src.tree)
    mods, from_names = aliases["mods"], aliases["from"]
    scopes = qualname_index(src.tree)
    findings: List[Finding] = []

    def emit(rule: str, node: ast.AST, message: str, suggestion: str) -> None:
        f = src.finding(CHECKER, rule, node, message,
                        scope=scopes.get(node, ""), suggestion=suggestion)
        if f is not None:
            findings.append(f)

    # -- statically-known sets in each function scope (for set-iteration) --
    set_vars: Dict[str, Set[str]] = {}

    def _is_set_expr(node: ast.AST, scope: str) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("set", "frozenset"):
            return True
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
            # set algebra: s - {...}, s | t — a set if either side is
            return (_is_set_expr(node.left, scope)
                    or _is_set_expr(node.right, scope))
        if isinstance(node, ast.Name):
            return node.id in set_vars.get(scope, set())
        return False

    for node in ast.walk(src.tree):
        scope = scopes.get(node, "")
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name) and \
                _is_set_expr(node.value, scope):
            set_vars.setdefault(scope, set()).add(node.targets[0].id)

    for node in ast.walk(src.tree):
        scope = scopes.get(node, "")

        # ---------------------------------------------------- unseeded-rng
        if isinstance(node, ast.Call):
            fname = dotted_name(node.func)
            if fname:
                parts = fname.split(".")
                head, tail = parts[0], parts[-1]
                np_random = (len(parts) >= 3 and head in mods["numpy"]
                             and parts[1] == "random")
                std_random = (len(parts) == 2 and head in mods["random"])
                bare_random = (len(parts) == 1
                               and tail in from_names["random"])
                if np_random or std_random or bare_random:
                    seeded_factory = (tail in _SEEDED_FACTORIES
                                      and (node.args or node.keywords))
                    if not seeded_factory:
                        if tail in _SEEDED_FACTORIES:
                            msg = (f"'{fname}()' without a seed draws entropy "
                                   f"from the OS — results are not a function "
                                   f"of the spec")
                            fix = f"pass an explicit seed: {fname}(seed)"
                        else:
                            msg = (f"'{fname}' uses global RNG state — "
                                   f"unseeded and shared across callers")
                            fix = ("thread a seeded np.random.default_rng"
                                   "(seed) / random.Random(seed) through "
                                   "instead")
                        emit("unseeded-rng", node, msg, fix)

            # ------------------------------------------- hash-randomization
            if isinstance(node.func, ast.Name) and node.func.id == "hash":
                emit("hash-randomization", node,
                     "builtin hash() is salted per process "
                     "(PYTHONHASHSEED) — not stable across runs",
                     "use zlib.crc32 / hashlib over an encoded key, or an "
                     "explicit index")

            # ------------------------------------------------ environ-read
            if fname and ((len(fname.split(".")) == 2
                           and fname.split(".")[0] in mods["os"]
                           and fname.split(".")[1] == "getenv")
                          or (fname.endswith(".environ.get")
                              and fname.split(".")[0] in mods["os"])):
                _check_environ(src, node, scopes, emit)

        # ------------------------------------------- environ subscript use
        if isinstance(node, ast.Subscript):
            base = dotted_name(node.value)
            if base and base.split(".")[0] in mods["os"] and \
                    base.endswith(".environ"):
                _check_environ(src, node, scopes, emit)

        # -------------------------------------------------------- wall-clock
        if isinstance(node, ast.Attribute):
            name = dotted_name(node)
            if name:
                parts = name.split(".")
                head, tail = parts[0], parts[-1]
                if len(parts) == 2 and head in mods["time"] and \
                        tail in _WALL_CLOCK_ATTRS and \
                        tail not in config.SANCTIONED_TIMERS:
                    emit("wall-clock", node,
                         f"'{name}' read in simulation scope — simulated "
                         f"time must come from the trace/event clock",
                         "pass 'now' in from the simulation clock, or mark "
                         "a live-side site with "
                         "'# repro-lint: allow[wall-clock]'")
                elif tail in _DATETIME_NOW and head in mods["datetime"]:
                    emit("wall-clock", node,
                         f"'{name}' reads the wall clock",
                         "inject timestamps via arguments/spec")
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            # from-imports: `from time import time` then bare `time()`
            if node.id in from_names["time"] and \
                    node.id in _WALL_CLOCK_ATTRS:
                emit("wall-clock", node,
                     f"'{node.id}' (imported from time) reads the wall clock",
                     "pass 'now' in from the simulation clock")

        # ----------------------------------------------------- set-iteration
        iters: List[ast.AST] = []
        if isinstance(node, (ast.For, ast.AsyncFor)):
            iters.append(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            iters.extend(g.iter for g in node.generators)
        elif isinstance(node, ast.Call):
            fname = dotted_name(node.func) or ""
            if (fname.endswith(".join") or fname.split(".")[-1] in
                    ("list", "tuple", "enumerate")) and node.args:
                iters.append(node.args[0])
        for it in iters:
            if _is_set_expr(it, scope):
                emit("set-iteration", it,
                     "iteration order over a set is hash-order — "
                     "nondeterministic across processes once it flows into "
                     "an ordered output",
                     "wrap in sorted(...) (or keep a list/dict, which "
                     "preserve insertion order)")

    return findings


def _check_environ(src: SourceFile, node: ast.AST, scopes, emit) -> None:
    fn = enclosing_function_name(scopes, node)
    if (src.rel, fn) in config.SANCTIONED_ENVIRON:
        return
    emit("environ-read", node,
         "os.environ access outside the declared config entry points "
         "(tools/analysis/config.py SANCTIONED_ENVIRON) — hidden "
         "configuration channels break spec-purity",
         "route the knob through the scenario spec / function arguments, "
         "or declare this function as an entry point in "
         "tools/analysis/config.py")
