import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the appropriate step function under the production mesh
with explicit in/out shardings, compiles it (SPMD partitioning included — sharding
mismatches, compile-time OOMs, and unsupported collectives all surface here), and
records ``memory_analysis`` / ``cost_analysis`` / parsed collective bytes to a JSON
artifact for the roofline analysis.

  train_4k      -> train_step   (fwd + bwd + AdamW, donated params/opt, ZeRO-1 opt)
  prefill_32k   -> prefill_step (builds the decode state)
  decode_32k    -> serve_step   (1 new token against a seq_len KV cache, donated)
  long_500k     -> serve_step   (sub-quadratic archs only; batch=1 shards the cache
                                 sequence over 'data' — see DESIGN.md §4/§5)

Usage:
  python -m repro.launch.dryrun --arch gemma2_27b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
# NOTE: no `from __future__ import annotations` here — the XLA_FLAGS lines above must
# stay the very first statements of the module (jax locks device count on first init).
import argparse
import json
import sys
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional

import numpy as np


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (step_fn, arg_specs (with shardings), out_shardings, donate)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, make_batch_specs
    from repro.models.api import make_prefill_step, make_serve_step, make_train_step
    from repro.models.config import SHAPES
    from repro.models.sharding import (batch_pspecs, decode_state_pspecs,
                                       mesh_axes, param_pspecs)
    from repro.models.transformer import init_decode_state, init_params
    from repro.optim import adamw_init

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    dp_axes, _ = mesh_axes(mesh)
    tp = mesh.shape["model"]
    dp = int(np.prod([mesh.shape[a] for a in dp_axes]))

    if shape_name == "long_500k" and not cfg.supports_long_context:
        raise SkipCell(f"{arch} is pure full-attention — long_500k skipped "
                       "(DESIGN.md §4)")

    key = jax.random.PRNGKey(0)
    params_abs = jax.eval_shape(lambda: init_params(key, cfg, jnp.bfloat16))
    p_specs = param_pspecs(cfg, params_abs, tp)
    ns = lambda spec: NamedSharding(mesh, spec)
    attach = lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=ns(s))
    params_in = jax.tree.map(attach, params_abs, p_specs)
    p_shardings = jax.tree.map(ns, p_specs)

    if shape.kind == "train":
        data = DataConfig(global_batch=shape.global_batch, seq_len=shape.seq_len)
        bspecs = make_batch_specs(cfg, data)
        batch_abs = {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in bspecs.items()}
        b_pspecs = batch_pspecs(cfg, batch_abs, dp_axes, dp)
        batch_in = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=ns(b_pspecs[k]))
                    for k, v in batch_abs.items()}
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        # ZeRO-1: shard optimizer moments over 'data' on the first unsharded,
        # divisible dim (params stay replicated over data; opt state is 4x params)
        def zero1(spec, leaf):
            dims = list(spec) + [None] * (leaf.ndim - len(spec))
            for i, (d, s) in enumerate(zip(leaf.shape, dims)):
                if s is None and d % mesh.shape["data"] == 0 and d >= mesh.shape["data"]:
                    dims[i] = "data"
                    break
            return P(*dims)
        mu_specs = jax.tree.map(zero1, p_specs, params_abs,
                                is_leaf=lambda x: isinstance(x, P))
        opt_specs = {"mu": mu_specs, "nu": mu_specs, "count": P()}
        opt_in = jax.tree.map(attach, opt_abs, opt_specs)
        opt_shardings = jax.tree.map(ns, opt_specs)
        step_in = jax.ShapeDtypeStruct((), jnp.int32, sharding=ns(P()))

        fn = make_train_step(cfg, remat="unit")
        return (fn, (params_in, opt_in, batch_in, step_in),
                (p_shardings, opt_shardings, None), (0, 1), cfg)

    if shape.kind == "prefill":
        data = DataConfig(global_batch=shape.global_batch, seq_len=shape.seq_len)
        bspecs = make_batch_specs(cfg, data)
        batch_abs = {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in bspecs.items()}
        b_pspecs = batch_pspecs(cfg, batch_abs, dp_axes, dp)
        batch_in = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=ns(b_pspecs[k]))
                    for k, v in batch_abs.items()}
        state_abs = jax.eval_shape(
            lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len,
                                      jnp.bfloat16))
        st_specs = decode_state_pspecs(cfg, state_abs, dp_axes, dp, tp,
                                       shape.global_batch)
        st_shardings = jax.tree.map(ns, st_specs)
        fn = make_prefill_step(cfg, state_len=shape.seq_len)
        return (fn, (params_in, batch_in), (None, st_shardings), (), cfg)

    # decode
    state_abs = jax.eval_shape(
        lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len,
                                  jnp.bfloat16))
    st_specs = decode_state_pspecs(cfg, state_abs, dp_axes, dp, tp,
                                   shape.global_batch)
    state_in = jax.tree.map(attach, state_abs, st_specs)
    st_shardings = jax.tree.map(ns, st_specs)
    batch_covers = shape.global_batch % dp == 0 and shape.global_batch >= dp
    tok_spec = P(dp_axes, None) if batch_covers else P(None, None)
    token_in = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32,
                                    sharding=ns(tok_spec))
    fn = make_serve_step(cfg)
    return (fn, (params_in, state_in, token_in), (None, st_shardings), (1,), cfg)


class SkipCell(Exception):
    pass


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_path: Optional[str] = None, verbose: bool = True) -> Dict[str, Any]:
    import jax

    from repro.launch.hlo_walk import analyze_module
    from repro.launch.hlo_analysis import (cost_summary,
                                           memory_summary, roofline_terms,
                                           PEAK_FLOPS)
    from repro.launch.mesh import make_production_mesh
    from repro.models.config import SHAPES

    t_start = time.perf_counter()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": n_chips,
        "status": "ok",
    }
    try:
        fn, arg_specs, out_shardings, donate, cfg = build_cell(arch, shape_name, mesh)
        with mesh:
            jitted = jax.jit(fn, out_shardings=out_shardings,
                             donate_argnums=donate)
            t0 = time.perf_counter()
            lowered = jitted.lower(*arg_specs)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()

        cost = cost_summary(compiled)              # raw XLA numbers (while-body x1!)
        mem = memory_summary(compiled)
        hlo_text = compiled.as_text()
        walk = analyze_module(hlo_text)            # trip-count-aware (see hlo_walk)
        record.update({
            "lower_s": t1 - t0, "compile_s": t2 - t1,
            "cost_raw": cost, "memory": mem, "hlo_walk": walk,
        })

        # roofline inputs (per device; cost_analysis of the SPMD module is per-device)
        shape = SHAPES[shape_name]
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        n_active = cfg.active_param_count()
        model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
        if shape.kind == "decode":
            # decode attention reads the KV cache: count 2*N*B for the matmuls only
            model_flops = 2 * n_active * shape.global_batch
        record["model_flops_global"] = float(model_flops)
        record["model_flops_per_device"] = float(model_flops / n_chips)
        rt = roofline_terms(walk["flops"], walk["bytes"],
                            walk["collective_ring_weighted_bytes"])
        rt["useful_flops_ratio"] = (record["model_flops_per_device"] /
                                    max(walk["flops"], 1.0))
        rt["mfu_upper_bound"] = (record["model_flops_per_device"] /
                                 max(rt["step_lower_bound_s"], 1e-30) / PEAK_FLOPS)
        record["roofline"] = rt
        if verbose:
            ma = mem.get("live_bytes", 0) / 1e9
            print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: "
                  f"compile={record['compile_s']:.1f}s "
                  f"flops/dev={walk['flops']:.3e} bytes/dev={walk['bytes']:.3e} "
                  f"coll/dev={walk['collective_ring_weighted_bytes']:.3e}B "
                  f"live={ma:.2f}GB bottleneck={rt['bottleneck']} "
                  f"useful={rt['useful_flops_ratio']:.2f}")
            print(f"[dryrun]   memory_analysis: {mem}")
    except SkipCell as e:
        record["status"] = "skipped"
        record["reason"] = str(e)
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: SKIPPED — {e}")
    except Exception as e:  # a failure here is a bug in the distribution config
        record["status"] = "failed"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: FAILED — {e}")
    record["wall_s"] = time.perf_counter() - t_start
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(record, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS
    from repro.models.config import SHAPES

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if not args.all:
        assert args.arch and args.shape
        rc = 0
        for mk in meshes:
            rec = run_cell(args.arch, args.shape, mk,
                           out_path=os.path.join(
                               args.out, f"{args.arch}__{args.shape}__{mk}.json"))
            rc |= int(rec["status"] == "failed")
        sys.exit(rc)

    # --all: one subprocess per cell (isolation: compile memory is reclaimed,
    # a single pathological cell cannot take down the sweep)
    import subprocess
    archs = [a for a in ARCH_IDS if a != "fnbench_tiny"]
    failures = 0
    for mk in meshes:
        for arch in archs:
            for shape_name in SHAPES:
                path = os.path.join(args.out, f"{arch}__{shape_name}__{mk}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[dryrun] skip existing {path}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name, "--mesh", mk,
                       "--out", args.out]
                t0 = time.perf_counter()
                try:
                    r = subprocess.run(cmd, timeout=args.timeout)
                    rc = r.returncode
                except subprocess.TimeoutExpired:
                    rc = -1
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape_name, "mesh": mk,
                                   "status": "failed",
                                   "error": f"timeout>{args.timeout}s"}, f)
                failures += int(rc != 0)
                print(f"[sweep] {arch} x {shape_name} x {mk}: rc={rc} "
                      f"({time.perf_counter() - t0:.0f}s)")
    print(f"[sweep] done, {failures} failures")
    sys.exit(int(failures > 0))


if __name__ == "__main__":
    main()
