"""Per-architecture smoke tests: reduced config, one forward + one train step on CPU,
asserting output shapes and no NaNs (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models.api import loss_fn, make_train_step
from repro.models.layers import padded_vocab
from repro.models.transformer import forward, init_decode_state, init_params
from repro.optim import adamw_init

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "audio_frames":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_enc_positions, cfg.d_model)) * 0.02,
            jnp.float32)
    elif cfg.frontend == "vision_patches":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_frontend_tokens, cfg.d_model)) * 0.02,
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_validates(arch):
    cfg = get_config(arch)
    cfg.validate()
    assert cfg.param_count() > 0
    assert cfg.active_param_count() <= cfg.param_count(include_embeddings=False)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_reduced(arch)
    params = init_params(KEY, cfg, jnp.float32)
    batch = _batch(cfg)
    fe = batch.get("frames", batch.get("patches"))
    logits, aux, state = forward(params, batch["tokens"], cfg,
                                 frontend_embeds=fe, make_state=True)
    B, S = batch["tokens"].shape
    S_total = S + (cfg.n_frontend_tokens if cfg.frontend == "vision_patches" else 0)
    assert logits.shape == (B, S_total, padded_vocab(cfg))
    assert not bool(jnp.isnan(logits).any())
    assert state is not None and int(state["pos"][0]) == S_total


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_reduced(arch)
    params = init_params(KEY, cfg, jnp.float32)
    opt = adamw_init(params)
    step = make_train_step(cfg, remat="none", total_steps=10)
    batch = _batch(cfg)
    new_p, new_o, metrics = step(params, opt, batch, jnp.int32(0))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    deltas = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_p)
    assert max(jax.tree.leaves(deltas)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_state_shapes(arch):
    cfg = get_reduced(arch)
    st = init_decode_state(cfg, batch=2, seq_len=32, dtype=jnp.float32)
    assert st["pos"].shape == (2,)
    leaves = jax.tree.leaves(st)
    assert all(l.ndim >= 0 for l in leaves)


def test_remat_matches_no_remat():
    cfg = get_reduced("qwen3_1_7b")
    params = init_params(KEY, cfg, jnp.float32)
    batch = _batch(cfg)
    l1, _ = loss_fn(params, batch, cfg, remat="none")
    l2, _ = loss_fn(params, batch, cfg, remat="unit")
    assert abs(float(l1) - float(l2)) < 1e-5
