"""WarmSwap page/image/pool/migration behaviour + hypothesis property tests."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    DependencyManager,
    LinkModel,
    RestorePolicy,
    build_image,
    materialize,
    paginate,
)
from repro.core.pages import materialize_leaf


# ---------------------------------------------------------------------------------
# Property: paginate/materialize round-trips any pytree exactly
# ---------------------------------------------------------------------------------

@st.composite
def pytrees(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    n = draw(st.integers(1, 6))
    tree = {}
    for i in range(n):
        ndim = draw(st.integers(1, 3))
        shape = tuple(draw(st.integers(1, 17)) for _ in range(ndim))
        dt = draw(st.sampled_from(["float32", "int32", "bfloat16", "uint8"]))
        if dt == "bfloat16":
            import ml_dtypes
            arr = rng.standard_normal(shape).astype(ml_dtypes.bfloat16)
        else:
            arr = (rng.standard_normal(shape) * 100).astype(dt)
        tree[f"leaf{i}"] = arr if i % 2 == 0 else {"nested": arr}
    return tree


@given(pytrees(), st.sampled_from([128, 4096, 1 << 20]))
@settings(max_examples=25, deadline=None)
def test_paginate_roundtrip_property(tree, page_size):
    store, table, treedef = paginate(tree, page_size=page_size)
    out = materialize(store, table, treedef)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert np.array_equal(np.asarray(a).view(np.uint8),
                              np.asarray(b).view(np.uint8))


@given(pytrees())
@settings(max_examples=10, deadline=None)
def test_metadata_much_smaller_than_image(tree):
    """Paper Table 3: process metadata << dependency image (for non-trivial images)."""
    store, table, treedef = paginate(tree, page_size=4096)
    if table.nbytes_payload > 100_000:
        assert table.metadata_bytes() < table.nbytes_payload / 5


def _params(seed=0, d=64):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (d, d)),
            "b": {"w": jax.random.normal(k, (d, 4 * d)),
                  "scale": jnp.zeros((d,))}}


# ---------------------------------------------------------------------------------
# Migration policies
# ---------------------------------------------------------------------------------

@pytest.mark.parametrize("policy", list(RestorePolicy))
def test_all_policies_restore_identical_params(policy):
    mgr = DependencyManager()
    mgr.register_image("img", "test", lambda: _params())
    restored = mgr.request_migration("img", policy)
    out = restored.as_pytree()
    for a, b in zip(jax.tree_util.tree_leaves(_params()),
                    jax.tree_util.tree_leaves(out)):
        assert np.allclose(np.asarray(a), np.asarray(b))


def test_lazy_restore_transfers_only_touched_pages():
    mgr = DependencyManager(page_size=1024)
    mgr.register_image("img", "test", lambda: _params(d=128))
    restored = mgr.request_migration("img", RestorePolicy.LAZY)
    key = restored.metadata.page_table.order[0]
    restored.fault(key)
    total_pages = restored.metadata.page_table.n_pages
    assert restored.stats.pages_transferred < total_pages
    assert restored.resident_fraction() < 1.0


def test_bulk_restore_streams_everything_after_first_fault():
    mgr = DependencyManager(page_size=1024)
    mgr.register_image("img", "test", lambda: _params(d=128))
    restored = mgr.request_migration("img", RestorePolicy.BULK)
    restored.fault(restored.metadata.page_table.order[0])
    restored.wait_all()
    assert restored.resident_fraction() == 1.0
    assert restored.stats.pages_transferred == restored.metadata.page_table.n_pages


def test_no_pageserver_is_one_big_request():
    mgr = DependencyManager(page_size=1024)
    mgr.register_image("img", "test", lambda: _params())
    restored = mgr.request_migration("img", RestorePolicy.NO_PAGESERVER)
    assert restored.stats.requests == 1
    assert restored.resident_fraction() == 1.0


@pytest.mark.parametrize("policy", [RestorePolicy.BULK, RestorePolicy.LAZY])
def test_restore_fault_storm_fetches_each_leaf_once(policy):
    """Regression: fault() and the background stream used to race on the same
    leaf — double page fetch, double-counted stats, concurrent _local writes.
    The per-leaf claim must keep pages_transferred == n_pages under a storm of
    concurrent faults."""
    import threading

    mgr = DependencyManager(page_size=1024)
    mgr.register_image("img", "test", lambda: _params(d=128))
    restored = mgr.request_migration("img", policy)
    keys = list(restored.metadata.page_table.order)
    errors = []

    def storm(order):
        try:
            for k in order:
                restored.fault(k)
        except Exception as exc:       # pragma: no cover - failure path
            errors.append(exc)

    threads = [threading.Thread(target=storm, args=(keys[::d],))
               for d in (1, -1, 1, -1)]
    for th in threads:
        th.start()
    restored.wait_all()
    for th in threads:
        th.join()
    assert not errors
    assert restored.resident_fraction() == 1.0
    # each leaf's page span crossed the link exactly once
    assert (restored.stats.pages_transferred
            == restored.metadata.page_table.n_pages)
    # and the restored tree is still byte-identical to the source
    for a, b in zip(jax.tree_util.tree_leaves(_params(d=128)),
                    jax.tree_util.tree_leaves(restored.as_pytree())):
        assert np.allclose(np.asarray(a), np.asarray(b))


def test_bulk_stream_death_does_not_deadlock_wait_all():
    """If the background stream thread dies mid-stream, wait_all() must retry
    the unfinished leaves inline instead of waiting forever on events the dead
    thread never set."""
    mgr = DependencyManager(page_size=1024)
    mgr.register_image("img", "test", lambda: _params(d=128))
    restored = mgr.request_migration("img", RestorePolicy.BULK)
    orig = restored._server.fetch_pages
    state = {"calls": 0}

    def flaky(first_page, n_pages):
        state["calls"] += 1
        if state["calls"] == 2:            # first background-stream fetch
            raise IOError("link flap")
        return orig(first_page, n_pages)

    restored._server.fetch_pages = flaky
    restored.fault(restored.metadata.page_table.order[0])   # starts the stream
    restored.wait_all()                    # must not hang; retries inline
    assert restored.resident_fraction() == 1.0


def test_restore_install_failure_surfaces_and_is_retryable():
    """A failed page fetch must release the per-leaf claim and wake waiters
    with an error — never deadlock them — and a retry must succeed."""
    mgr = DependencyManager(page_size=1024)
    mgr.register_image("img", "test", lambda: _params())
    restored = mgr.request_migration("img", RestorePolicy.LAZY)
    key = restored.metadata.page_table.order[0]
    orig = restored._server.fetch_pages
    state = {"fail": True}

    def flaky(first_page, n_pages):
        if state["fail"]:
            state["fail"] = False
            raise IOError("link down")
        return orig(first_page, n_pages)

    restored._server.fetch_pages = flaky
    with pytest.raises(IOError):
        restored.fault(key)
    assert restored.resident_fraction() == 0.0
    out = restored.fault(key)                  # claim released: retry works
    assert out.shape == restored.metadata.page_table.entries[key].shape
    restored.wait_all()
    assert restored.resident_fraction() == 1.0


# ---------------------------------------------------------------------------------
# Pool behaviour
# ---------------------------------------------------------------------------------

def test_pool_shares_one_image_across_functions():
    """Pool memory is O(#images), not O(#functions) — the paper's core claim."""
    mgr = DependencyManager()
    mgr.register_image("shared", "test", lambda: _params(d=128))
    size_one = mgr.pool_bytes()
    for _ in range(10):
        r = mgr.request_migration("shared", RestorePolicy.BULK)
        r.as_pytree()
        mgr.release("shared")
    assert mgr.pool_bytes() == size_one
    assert mgr.stats.builds == 1


def test_pool_evict_to_disk_and_revive():
    with tempfile.TemporaryDirectory() as tmp:
        mgr = DependencyManager(disk_dir=tmp)
        mgr.register_image("img", "test", lambda: _params(seed=3))
        before = mgr.request_migration("img", RestorePolicy.BULK).as_pytree()
        mgr.release("img")
        mgr.evict("img")
        assert not mgr.has_live("img")
        after = mgr.request_migration("img", RestorePolicy.BULK).as_pytree()
        for a, b in zip(jax.tree_util.tree_leaves(before),
                        jax.tree_util.tree_leaves(after)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert mgr.stats.revivals == 1
        assert mgr.stats.builds == 1  # revive did NOT re-run initialization


def test_pool_capacity_lru_eviction():
    with tempfile.TemporaryDirectory() as tmp:
        mgr = DependencyManager(capacity_bytes=1 << 20, disk_dir=tmp,
                                page_size=4096)
        mgr.register_image("a", "t", lambda: _params(seed=1, d=128))  # ~330KB
        mgr.register_image("b", "t", lambda: _params(seed=2, d=128))
        mgr.register_image("c", "t", lambda: _params(seed=3, d=128))
        mgr.register_image("d", "t", lambda: _params(seed=4, d=128))
        assert mgr.pool_bytes() <= 1 << 20
        assert mgr.stats.evictions >= 1


def test_reshard_image_preserves_values():
    mgr = DependencyManager()
    mgr.register_image("img", "test", lambda: _params(seed=5))
    orig = mgr.request_migration("img", RestorePolicy.BULK).as_pytree()
    mgr.release("img")
    mgr.reshard_image("img", lambda p: jax.tree.map(np.asarray, p))
    again = mgr.request_migration("img", RestorePolicy.BULK).as_pytree()
    for a, b in zip(jax.tree_util.tree_leaves(orig),
                    jax.tree_util.tree_leaves(again)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_remote_link_adds_latency():
    mgr = DependencyManager()
    mgr.register_image("img", "test", lambda: _params(d=256))
    import time
    t0 = time.perf_counter()
    r = mgr.request_migration("img", RestorePolicy.NO_LAZY,
                              LinkModel(latency_s=0.005))
    local = time.perf_counter() - t0
    assert local >= 0.005  # at least the per-request latency
